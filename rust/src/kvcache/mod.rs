//! Paged KV-cache memory substrate: tier accounting, block paging and the
//! GPU↔host transfer model.
//!
//! Following vLLM (§5.1 "RAGCache stores the key-value tensors in
//! non-continuous memory blocks"), KV memory is allocated in fixed-size
//! token pages; a document's footprint is its token count rounded up to
//! whole pages. Two tiers form the hierarchy: GPU (fast, small) and host
//! (slow, large), connected by a PCIe-like [`TransferModel`].
//!
//! The same two [`TierAllocator`]s back both residency forms the tree
//! layer supports: prefix-tree nodes AND owned chunk-cache entries
//! (`--chunk-cache on`, position-independent reuse) draw from one
//! shared budget per tier, so enabling the chunk cache never grows the
//! configured KV memory — see `crate::tree::chunk_cache`.
//!
//! # Three-tier cascade (`--disk on`)
//!
//! An optional NVMe-backed third tier (`crate::tree::disk_tier`) sits
//! below the host. Eviction then cascades instead of dropping:
//!
//! ```text
//!   GPU --swap-out--> host --spill--> disk --evict--> dropped
//!    ^                 ^                |
//!    '--(PCIe H2D)-----'--(restage)-----'
//! ```
//!
//! Each demotion moves a payload exactly one level; a victim only
//! descends when the level below admitted it (`NoRoom` degrades to the
//! pre-disk drop, bit-identical to `--disk off`). Restage is the
//! reverse walk: an admitted request that matches a disk-resident node
//! pulls it back to host, and the ordinary promotion path lifts it to
//! GPU.
//!
//! # Burst-charging contract
//!
//! The latency model charges tier traffic asymmetrically, mirroring
//! the H2D rule the PCIe [`TransferModel`] already follows:
//!
//! - **Spills (downward) are counted, never charged.** Host→disk
//!   writes ride the async staging queue (`flush_disk_staging`) off
//!   the critical path; they appear in `disk_spills`/`disk_spill_bytes`
//!   and in `Transfers::h2d_bytes`, but add zero seconds to any
//!   request.
//! - **Restages (upward) are charged as ONE coalesced read burst per
//!   admitted batch**, exactly like the single PCIe H2D burst: all
//!   disk reads an admission triggers sum into
//!   `Admission::disk_read_bytes()` (= `Transfers::d2h_bytes`) and are
//!   charged once at NVMe bandwidth plus one access latency — in the
//!   simulator as a staged read burst, in the real path overlapped
//!   with retrieval. `disk_read_bytes` is deliberately NOT folded into
//!   `transfer_bytes()`, so PCIe and NVMe bursts price at their own
//!   bandwidths.

pub mod payload;

pub use payload::KvPayload;

/// Cache tier: where a node's KV tensors live.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Tier {
    Gpu,
    Host,
}

/// Byte-accounting allocator for one tier.
#[derive(Debug, Clone)]
pub struct TierAllocator {
    capacity: u64,
    used: u64,
}

impl TierAllocator {
    pub fn new(capacity: u64) -> Self {
        TierAllocator { capacity, used: 0 }
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn used(&self) -> u64 {
        self.used
    }

    pub fn free(&self) -> u64 {
        // `used <= capacity` is an invariant, but a buggy caller that
        // slipped past the release() debug-assert in a release build must
        // degrade to "no free space", not wrap to ~u64::MAX.
        self.capacity.saturating_sub(self.used)
    }

    /// Whether `bytes` could ever fit in this tier.
    pub fn fits_at_all(&self, bytes: u64) -> bool {
        bytes <= self.capacity
    }

    /// Try to reserve; returns false (unchanged) if it does not fit.
    #[must_use]
    pub fn alloc(&mut self, bytes: u64) -> bool {
        match self.used.checked_add(bytes) {
            Some(total) if total <= self.capacity => {
                self.used = total;
                true
            }
            _ => false,
        }
    }

    /// Checked capacity update — the primitive behind demand-driven
    /// cross-shard tier rebalancing. Growing always succeeds; shrinking
    /// succeeds only when current usage already fits the new capacity
    /// (the caller must evict to fit FIRST — see
    /// [`crate::tree::KnowledgeTree::resize_budgets`]). Returns whether
    /// the capacity changed; a refused shrink leaves the allocator
    /// untouched, so `used <= capacity` holds unconditionally.
    #[must_use]
    pub fn set_capacity(&mut self, capacity: u64) -> bool {
        if self.used > capacity {
            return false;
        }
        self.capacity = capacity;
        true
    }

    /// Release a prior reservation. Releasing more than is in use is a
    /// caller bug: loud in debug builds, saturating (never wrapping) in
    /// release builds.
    pub fn release(&mut self, bytes: u64) {
        debug_assert!(
            self.used >= bytes,
            "releasing {bytes} B but only {} B in use",
            self.used
        );
        self.used = self.used.saturating_sub(bytes);
    }
}

/// Page-rounding for vLLM-style block allocation.
#[derive(Debug, Clone, Copy)]
pub struct PageSpec {
    /// Tokens per page (vLLM block size).
    pub block_tokens: usize,
    /// KV bytes per token (model-dependent, paper Table 1).
    pub kv_bytes_per_token: usize,
}

impl PageSpec {
    /// Pages needed for `tokens`.
    pub fn pages(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens.max(1))
    }

    /// Page-rounded byte footprint of `tokens` of KV cache.
    pub fn bytes(&self, tokens: usize) -> u64 {
        (self.pages(tokens) * self.block_tokens * self.kv_bytes_per_token)
            as u64
    }

    /// Exact (unrounded) bytes — the amount actually moved over PCIe.
    pub fn payload_bytes(&self, tokens: usize) -> u64 {
        (tokens * self.kv_bytes_per_token) as u64
    }
}

/// GPU↔host link model (PCIe 4.0/5.0 ×16 in the paper's testbeds).
#[derive(Debug, Clone, Copy)]
pub struct TransferModel {
    /// Effective unidirectional bandwidth, bytes/second.
    pub bandwidth_bps: f64,
    /// Fixed per-transfer latency, seconds (driver + DMA setup).
    pub latency_s: f64,
}

impl TransferModel {
    /// PCIe 4.0 ×16 — the A10G testbed. Nominal 32 GB/s; block-granular
    /// KV copies achieve ~12 GB/s effective (calibrated to the paper's
    /// Fig. 4 cache-hit-with-transfer ratio of ~3.9×).
    pub fn pcie4() -> Self {
        TransferModel {
            bandwidth_bps: 12.0e9,
            latency_s: 20e-6,
        }
    }

    /// PCIe 5.0 ×16 — the H800 testbed (~25 GB/s effective).
    pub fn pcie5() -> Self {
        TransferModel {
            bandwidth_bps: 25.0e9,
            latency_s: 20e-6,
        }
    }

    /// Seconds to move `bytes` one way. One call = one DMA burst = one
    /// setup latency: batched admission coalesces a whole batch's bytes
    /// into a single call ([`crate::controller::BatchAdmission`]), so a
    /// B-member batch saves `(B - 1) · latency_s` over per-request
    /// transfers.
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            0.0
        } else {
            self.latency_s + bytes as f64 / self.bandwidth_bps
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocator_accounting() {
        let mut a = TierAllocator::new(100);
        assert!(a.alloc(60));
        assert_eq!(a.used(), 60);
        assert!(!a.alloc(50), "over-capacity alloc must fail");
        assert_eq!(a.used(), 60, "failed alloc leaves state unchanged");
        assert!(a.alloc(40));
        assert_eq!(a.free(), 0);
        a.release(30);
        assert_eq!(a.used(), 70);
    }

    #[test]
    fn set_capacity_grows_freely_and_shrinks_checked() {
        let mut a = TierAllocator::new(100);
        assert!(a.alloc(60));
        // Growing always succeeds.
        assert!(a.set_capacity(200));
        assert_eq!(a.capacity(), 200);
        assert_eq!(a.free(), 140);
        // Shrinking to >= used succeeds, even exactly to used.
        assert!(a.set_capacity(60));
        assert_eq!(a.capacity(), 60);
        assert_eq!(a.free(), 0);
        // Shrinking below used is refused and leaves state untouched.
        assert!(!a.set_capacity(59));
        assert_eq!(a.capacity(), 60);
        assert_eq!(a.used(), 60);
        // After releasing, the same shrink fits.
        a.release(10);
        assert!(a.set_capacity(59));
        assert_eq!(a.free(), 9);
    }

    #[test]
    fn alloc_overflow_is_rejected() {
        let mut a = TierAllocator::new(u64::MAX);
        assert!(a.alloc(u64::MAX - 1));
        // used + bytes would overflow u64: must refuse, not wrap.
        assert!(!a.alloc(2));
        assert_eq!(a.used(), u64::MAX - 1);
        assert_eq!(a.free(), 1);
    }

    #[test]
    fn free_is_exact_at_capacity() {
        let mut a = TierAllocator::new(64);
        assert!(a.alloc(64));
        assert_eq!(a.free(), 0);
        a.release(64);
        assert_eq!(a.free(), 64);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "releasing")]
    fn over_release_asserts_in_debug() {
        let mut a = TierAllocator::new(100);
        assert!(a.alloc(10));
        a.release(11);
    }

    #[test]
    #[cfg(not(debug_assertions))]
    fn over_release_saturates_in_release() {
        let mut a = TierAllocator::new(100);
        assert!(a.alloc(10));
        a.release(11);
        assert_eq!(a.used(), 0, "saturates instead of wrapping");
        assert_eq!(a.free(), 100);
    }

    #[test]
    fn page_rounding() {
        let spec = PageSpec {
            block_tokens: 16,
            kv_bytes_per_token: 1024,
        };
        assert_eq!(spec.pages(0), 0);
        assert_eq!(spec.pages(1), 1);
        assert_eq!(spec.pages(16), 1);
        assert_eq!(spec.pages(17), 2);
        assert_eq!(spec.bytes(17), 2 * 16 * 1024);
        assert_eq!(spec.payload_bytes(17), 17 * 1024);
    }

    #[test]
    fn transfer_time_scales() {
        let t = TransferModel::pcie4();
        assert_eq!(t.transfer_time(0), 0.0);
        let one_mib = t.transfer_time(1 << 20);
        let two_mib = t.transfer_time(2 << 20);
        assert!(two_mib > one_mib);
        // 1 GiB at 12 GB/s effective ≈ 89 ms.
        let one_gib = t.transfer_time(1 << 30);
        assert!((one_gib - 0.0895).abs() < 0.005, "{one_gib}");
    }

    /// The batched-admission win (ROADMAP "Batched H2D transfers"): a
    /// coalesced burst pays the DMA setup once, and the saving is
    /// exactly the (B − 1) setup latencies — bandwidth time is linear
    /// in bytes either way.
    #[test]
    fn coalesced_burst_beats_serial_bursts() {
        for t in [TransferModel::pcie4(), TransferModel::pcie5()] {
            let (a, b, c) = (1u64 << 20, 3 << 20, 7 << 20);
            let coalesced = t.transfer_time(a + b + c);
            let serial = t.transfer_time(a)
                + t.transfer_time(b)
                + t.transfer_time(c);
            assert!(coalesced < serial);
            assert!(
                (serial - coalesced - 2.0 * t.latency_s).abs() < 1e-12,
                "saving is exactly two setup latencies"
            );
        }
    }

    #[test]
    fn paper_kv_sizes() {
        // Table 1: LLaMA2-7B = 0.5 MiB/token; a 3718-token document
        // (mean Wikipedia length, Fig. 3) is ~1.8 GiB of KV.
        let spec = PageSpec {
            block_tokens: 16,
            kv_bytes_per_token: 512 * 1024,
        };
        let doc = spec.payload_bytes(3718);
        assert!((doc as f64 / (1 << 30) as f64 - 1.81).abs() < 0.05);
    }
}
