//! Discrete-event simulation substrate.
//!
//! The paper's experiments run on A10G/H800 GPUs; we reproduce them with a
//! discrete-event simulation driven by the analytic cost model
//! ([`crate::llm::cost_model`]). The controller is written against the
//! [`Clock`] abstraction so the identical scheduling/caching/pipelining
//! code also runs in real time for the PJRT-backed end-to-end path.
//!
//! ```text
//!            SimClock (single time authority)
//!                 ▲ advance_to(t)
//!                 │
//!   EventScheduler<E>  ──  binary heap on (time, seq)
//!     schedule(t, e) → EventHandle { slot, gen }
//!     cancel(handle)   O(log n) amortised: the slot is freed now,
//!                      the heap entry dies lazily at pop when its
//!                      generation stamp no longer matches
//!     pop() → (t, e)   total order: time first, then schedule seq —
//!                      two runs issuing the same schedule() calls
//!                      replay the identical event order, bit for bit
//! ```
//!
//! [`EventScheduler`] is the spine of the open-loop simulator
//! ([`crate::controller::sim_server`]): arrivals fire at their trace
//! timestamps regardless of engine occupancy, and the admission
//! controller cancels per-request deadline/stage events through the
//! generation-stamped handles. [`EventQueue`] is the original
//! cancellation-free wrapper, kept for callers that only need ordering.

use crate::util::heap::MinHeap;
use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

/// A source of "now" in seconds. Virtual in simulation, monotonic wall
/// clock in real serving.
pub trait Clock {
    fn now(&self) -> f64;
}

/// Wall-clock time since construction.
#[derive(Debug)]
pub struct RealClock {
    start: Instant,
}

impl RealClock {
    pub fn new() -> Self {
        RealClock {
            start: Instant::now(),
        }
    }
}

impl Default for RealClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for RealClock {
    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Shared virtual clock advanced by the event loop.
#[derive(Debug, Clone, Default)]
pub struct SimClock {
    now: Rc<RefCell<f64>>,
}

impl SimClock {
    pub fn new() -> Self {
        SimClock::default()
    }

    pub fn handle(&self) -> SimClock {
        SimClock {
            now: Rc::clone(&self.now),
        }
    }

    pub fn advance_to(&self, t: f64) {
        let mut now = self.now.borrow_mut();
        debug_assert!(t + 1e-12 >= *now, "time going backwards: {t} < {now}");
        if t > *now {
            *now = t;
        }
    }
}

impl Clock for SimClock {
    fn now(&self) -> f64 {
        *self.now.borrow()
    }
}

/// Future event queue keyed by virtual time.
///
/// Generic over the event payload; the controller defines its own event
/// enum. FIFO tie-breaking (via [`MinHeap`]) keeps replays deterministic.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: MinHeap<E>,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        EventQueue {
            heap: MinHeap::new(),
        }
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `event` at absolute time `t`.
    pub fn schedule(&mut self, t: f64, event: E) {
        self.heap.push(t, event);
    }

    /// Pop the earliest event, if any.
    pub fn next(&mut self) -> Option<(f64, E)> {
        self.heap.pop()
    }

    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek_key()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// Handle to one scheduled [`EventScheduler`] event.
///
/// Generation-stamped: when the underlying slot is freed (the event
/// fired or was cancelled) the generation advances, so a stale handle
/// held past its event's lifetime can never cancel an unrelated later
/// event that happens to reuse the slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EventHandle {
    slot: u32,
    gen: u32,
}

/// Cancellable discrete-event scheduler.
///
/// A binary heap keyed `(time, schedule-seq)` — FIFO among same-time
/// events, so replays are deterministic — plus a slot table holding the
/// payloads. [`EventScheduler::cancel`] frees the slot immediately and
/// leaves the heap entry to be skipped lazily at pop time (its
/// generation stamp no longer matches), keeping both `schedule` and
/// `cancel` O(log n) amortised.
///
/// Pop order is identical to [`EventQueue`] for the same sequence of
/// `schedule` calls: cancellation-free users of either see the same
/// replay, bit for bit.
#[derive(Debug)]
pub struct EventScheduler<E> {
    heap: MinHeap<(u32, u32)>,
    slots: Vec<Option<E>>,
    gens: Vec<u32>,
    free: Vec<u32>,
    live: usize,
}

impl<E> Default for EventScheduler<E> {
    fn default() -> Self {
        EventScheduler {
            heap: MinHeap::new(),
            slots: Vec::new(),
            gens: Vec::new(),
            free: Vec::new(),
            live: 0,
        }
    }
}

impl<E> EventScheduler<E> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `event` at absolute time `t`; the returned handle
    /// cancels it (and only it) until it fires.
    pub fn schedule(&mut self, t: f64, event: E) -> EventHandle {
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                self.slots.push(None);
                self.gens.push(0);
                (self.slots.len() - 1) as u32
            }
        };
        self.slots[slot as usize] = Some(event);
        let gen = self.gens[slot as usize];
        self.heap.push(t, (slot, gen));
        self.live += 1;
        EventHandle { slot, gen }
    }

    /// Cancel the event behind `handle`. Returns `false` (and does
    /// nothing) when it already fired or was already cancelled.
    pub fn cancel(&mut self, handle: EventHandle) -> bool {
        let i = handle.slot as usize;
        if i >= self.slots.len()
            || self.gens[i] != handle.gen
            || self.slots[i].is_none()
        {
            return false;
        }
        self.slots[i] = None;
        self.gens[i] = self.gens[i].wrapping_add(1);
        self.free.push(handle.slot);
        self.live -= 1;
        true
    }

    /// Pop the earliest live event; cancelled heap entries are skipped.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        while let Some((t, (slot, gen))) = self.heap.pop() {
            let i = slot as usize;
            if self.gens[i] != gen {
                continue; // cancelled: slot already freed (or reused)
            }
            let ev = self.slots[i].take().expect("live slot has payload");
            self.gens[i] = self.gens[i].wrapping_add(1);
            self.free.push(slot);
            self.live -= 1;
            return Some((t, ev));
        }
        None
    }

    /// Time of the earliest live event. Purges dead heap heads so the
    /// answer is exact, not an underestimate from a cancelled entry.
    pub fn peek_time(&mut self) -> Option<f64> {
        loop {
            match self.heap.peek() {
                None => return None,
                Some((t, &(slot, gen))) => {
                    if self.gens[slot as usize] == gen {
                        return Some(t);
                    }
                }
            }
            self.heap.pop();
        }
    }

    /// Live (un-cancelled, un-fired) events.
    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_clock_advances() {
        let clk = SimClock::new();
        assert_eq!(clk.now(), 0.0);
        clk.advance_to(1.5);
        assert_eq!(clk.now(), 1.5);
        let h = clk.handle();
        h.advance_to(2.0);
        assert_eq!(clk.now(), 2.0);
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn sim_clock_rejects_backwards() {
        let clk = SimClock::new();
        clk.advance_to(2.0);
        clk.advance_to(1.0);
    }

    #[test]
    fn event_queue_orders_events() {
        let mut q = EventQueue::new();
        q.schedule(2.0, "b");
        q.schedule(1.0, "a");
        q.schedule(3.0, "c");
        assert_eq!(q.peek_time(), Some(1.0));
        assert_eq!(q.next(), Some((1.0, "a")));
        assert_eq!(q.next(), Some((2.0, "b")));
        assert_eq!(q.next(), Some((3.0, "c")));
        assert!(q.next().is_none());
    }

    #[test]
    fn event_queue_fifo_ties() {
        let mut q = EventQueue::new();
        q.schedule(1.0, 1);
        q.schedule(1.0, 2);
        q.schedule(1.0, 3);
        assert_eq!(q.next().unwrap().1, 1);
        assert_eq!(q.next().unwrap().1, 2);
        assert_eq!(q.next().unwrap().1, 3);
    }

    #[test]
    fn real_clock_monotonic() {
        let c = RealClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    fn scheduler_orders_and_breaks_ties_fifo() {
        let mut s = EventScheduler::new();
        s.schedule(2.0, "late");
        s.schedule(1.0, "a");
        s.schedule(1.0, "b");
        assert_eq!(s.len(), 3);
        assert_eq!(s.peek_time(), Some(1.0));
        assert_eq!(s.pop(), Some((1.0, "a")));
        assert_eq!(s.pop(), Some((1.0, "b")));
        assert_eq!(s.pop(), Some((2.0, "late")));
        assert_eq!(s.pop(), None);
        assert!(s.is_empty());
    }

    #[test]
    fn cancelled_events_never_fire() {
        let mut s = EventScheduler::new();
        let a = s.schedule(1.0, "a");
        let b = s.schedule(2.0, "b");
        s.schedule(3.0, "c");
        assert!(s.cancel(b));
        assert!(!s.cancel(b), "double cancel is a no-op");
        assert_eq!(s.len(), 2);
        assert_eq!(s.pop(), Some((1.0, "a")));
        assert!(!s.cancel(a), "cancel after fire is a no-op");
        assert_eq!(s.pop(), Some((3.0, "c")));
        assert_eq!(s.pop(), None);
    }

    #[test]
    fn stale_handle_cannot_cancel_slot_reuse() {
        let mut s = EventScheduler::new();
        let a = s.schedule(1.0, "a");
        s.pop(); // frees a's slot
        let b = s.schedule(2.0, "b"); // reuses the slot, new generation
        assert!(!s.cancel(a), "stale handle must not hit the new event");
        assert_eq!(s.pop(), Some((2.0, "b")));
        assert!(!s.cancel(b));
    }

    #[test]
    fn cancelled_head_does_not_lie_in_peek() {
        let mut s = EventScheduler::new();
        let a = s.schedule(1.0, "a");
        s.schedule(5.0, "b");
        s.cancel(a);
        assert_eq!(s.peek_time(), Some(5.0));
        assert_eq!(s.pop(), Some((5.0, "b")));
    }

    #[test]
    fn schedule_during_drain_lands_in_order() {
        // The schedule-during-handler shape: popping an event schedules
        // another at a later time; it must slot into the total order.
        let mut s = EventScheduler::new();
        s.schedule(1.0, 1u32);
        s.schedule(3.0, 3u32);
        let mut fired = Vec::new();
        while let Some((t, e)) = s.pop() {
            fired.push(e);
            if e == 1 {
                s.schedule(t + 1.0, 2u32);
            }
        }
        assert_eq!(fired, vec![1, 2, 3]);
    }

    #[test]
    fn scheduler_matches_event_queue_replay() {
        // Same schedule() call sequence → same pop order as EventQueue,
        // the conformance contract the sim server's --shed off relies on.
        let mut q = EventQueue::new();
        let mut s = EventScheduler::new();
        let times = [3.0, 1.0, 2.0, 1.0, 3.0, 0.5, 2.0];
        for (i, &t) in times.iter().enumerate() {
            q.schedule(t, i);
            s.schedule(t, i);
        }
        loop {
            let a = q.next();
            let b = s.pop();
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
        }
    }
}
