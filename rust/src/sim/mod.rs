//! Discrete-event simulation substrate.
//!
//! The paper's experiments run on A10G/H800 GPUs; we reproduce them with a
//! discrete-event simulation driven by the analytic cost model
//! ([`crate::llm::cost_model`]). The controller is written against the
//! [`Clock`] abstraction so the identical scheduling/caching/pipelining
//! code also runs in real time for the PJRT-backed end-to-end path.

use crate::util::heap::MinHeap;
use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

/// A source of "now" in seconds. Virtual in simulation, monotonic wall
/// clock in real serving.
pub trait Clock {
    fn now(&self) -> f64;
}

/// Wall-clock time since construction.
#[derive(Debug)]
pub struct RealClock {
    start: Instant,
}

impl RealClock {
    pub fn new() -> Self {
        RealClock {
            start: Instant::now(),
        }
    }
}

impl Default for RealClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for RealClock {
    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Shared virtual clock advanced by the event loop.
#[derive(Debug, Clone, Default)]
pub struct SimClock {
    now: Rc<RefCell<f64>>,
}

impl SimClock {
    pub fn new() -> Self {
        SimClock::default()
    }

    pub fn handle(&self) -> SimClock {
        SimClock {
            now: Rc::clone(&self.now),
        }
    }

    pub fn advance_to(&self, t: f64) {
        let mut now = self.now.borrow_mut();
        debug_assert!(t + 1e-12 >= *now, "time going backwards: {t} < {now}");
        if t > *now {
            *now = t;
        }
    }
}

impl Clock for SimClock {
    fn now(&self) -> f64 {
        *self.now.borrow()
    }
}

/// Future event queue keyed by virtual time.
///
/// Generic over the event payload; the controller defines its own event
/// enum. FIFO tie-breaking (via [`MinHeap`]) keeps replays deterministic.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: MinHeap<E>,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        EventQueue {
            heap: MinHeap::new(),
        }
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `event` at absolute time `t`.
    pub fn schedule(&mut self, t: f64, event: E) {
        self.heap.push(t, event);
    }

    /// Pop the earliest event, if any.
    pub fn next(&mut self) -> Option<(f64, E)> {
        self.heap.pop()
    }

    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek_key()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_clock_advances() {
        let clk = SimClock::new();
        assert_eq!(clk.now(), 0.0);
        clk.advance_to(1.5);
        assert_eq!(clk.now(), 1.5);
        let h = clk.handle();
        h.advance_to(2.0);
        assert_eq!(clk.now(), 2.0);
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn sim_clock_rejects_backwards() {
        let clk = SimClock::new();
        clk.advance_to(2.0);
        clk.advance_to(1.0);
    }

    #[test]
    fn event_queue_orders_events() {
        let mut q = EventQueue::new();
        q.schedule(2.0, "b");
        q.schedule(1.0, "a");
        q.schedule(3.0, "c");
        assert_eq!(q.peek_time(), Some(1.0));
        assert_eq!(q.next(), Some((1.0, "a")));
        assert_eq!(q.next(), Some((2.0, "b")));
        assert_eq!(q.next(), Some((3.0, "c")));
        assert!(q.next().is_none());
    }

    #[test]
    fn event_queue_fifo_ties() {
        let mut q = EventQueue::new();
        q.schedule(1.0, 1);
        q.schedule(1.0, 2);
        q.schedule(1.0, 3);
        assert_eq!(q.next().unwrap().1, 1);
        assert_eq!(q.next().unwrap().1, 2);
        assert_eq!(q.next().unwrap().1, 3);
    }

    #[test]
    fn real_clock_monotonic() {
        let c = RealClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }
}
