//! Synthetic embedding model (replaces OpenAI text-embedding-3-small).
//!
//! Documents get deterministic, seed-derived Gaussian embeddings; queries
//! targeting a document are its embedding plus controlled noise. This
//! imposes a well-defined nearest-neighbour structure so the retrieval
//! layer behaves like the paper's setup while the *access pattern* (which
//! document each request targets) is imposed by the workload sampler —
//! matching the paper's observation (Fig. 6) that the skew is a property
//! of the question distribution, not of the embedding model.
//!
//! Three "embedding model" variants (different seeds → different geometry)
//! reproduce Fig. 6a's embedding-model sweep.

use crate::util::Rng;

/// Deterministic embedding generator.
#[derive(Debug, Clone)]
pub struct EmbeddingModel {
    dim: usize,
    seed: u64,
}

impl EmbeddingModel {
    pub fn new(dim: usize, seed: u64) -> Self {
        assert!(dim > 0);
        EmbeddingModel { dim, seed }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// The embedding of document `id` — unit-normalised Gaussian,
    /// deterministic in `(seed, id)`.
    pub fn document(&self, id: u32) -> Vec<f32> {
        let mut rng = Rng::new(
            self.seed
                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                .wrapping_add(id as u64),
        );
        let mut v: Vec<f32> =
            (0..self.dim).map(|_| rng.gaussian() as f32).collect();
        normalize(&mut v);
        v
    }

    /// A query embedding aimed at `target`: the document embedding plus
    /// isotropic noise of relative scale `noise` (0 = exact hit).
    pub fn query(&self, target: u32, noise: f64, rng: &mut Rng) -> Vec<f32> {
        let mut v = self.document(target);
        for x in v.iter_mut() {
            *x += (rng.gaussian() * noise) as f32;
        }
        normalize(&mut v);
        v
    }
}

fn normalize(v: &mut [f32]) {
    let norm: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
    if norm > 0.0 {
        for x in v.iter_mut() {
            *x /= norm;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vectordb::distance::l2_sq;

    #[test]
    fn deterministic_embeddings() {
        let em = EmbeddingModel::new(32, 1);
        assert_eq!(em.document(5), em.document(5));
        assert_ne!(em.document(5), em.document(6));
    }

    #[test]
    fn embeddings_unit_norm() {
        let em = EmbeddingModel::new(16, 2);
        for id in [0u32, 7, 1000] {
            let v = em.document(id);
            let n: f32 = v.iter().map(|x| x * x).sum();
            assert!((n - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn zero_noise_query_is_exact() {
        let em = EmbeddingModel::new(16, 3);
        let mut rng = Rng::new(1);
        let q = em.query(9, 0.0, &mut rng);
        assert!(l2_sq(&q, &em.document(9)) < 1e-10);
    }

    #[test]
    fn noisy_query_still_nearest_to_target() {
        let em = EmbeddingModel::new(32, 4);
        let mut rng = Rng::new(2);
        for target in [1u32, 50, 200] {
            let q = em.query(target, 0.05, &mut rng);
            let d_target = l2_sq(&q, &em.document(target));
            // Closer to the target than to 50 random other docs.
            for other in 0..50u32 {
                if other == target {
                    continue;
                }
                assert!(d_target < l2_sq(&q, &em.document(other)));
            }
        }
    }

    #[test]
    fn different_seeds_give_different_geometry() {
        let a = EmbeddingModel::new(16, 1).document(3);
        let b = EmbeddingModel::new(16, 2).document(3);
        assert!(l2_sq(&a, &b) > 0.1);
    }
}
