//! Benchmark harness (criterion replacement).
//!
//! Each `rust/benches/*.rs` binary (`harness = false`) reproduces one
//! paper figure/table: it builds a workload, runs the system(s), prints
//! the same rows/series the paper reports, and appends machine-readable
//! JSON to `bench_out/<name>.json` so EXPERIMENTS.md can be regenerated.

use crate::config::SystemConfig;
use crate::controller::{RetrievalTiming, SimOutcome, SimServer};
use crate::util::json::Json;
use crate::util::Summary;
use crate::workload::{datasets::DatasetProfile, Corpus, Trace};
use std::io::Write;
use std::time::Instant;

/// Run one full-system simulation — the shared driver for the figure
/// benches. Corpus and trace are derived deterministically from `seed`.
pub fn run_sim(
    cfg: &SystemConfig,
    profile: &DatasetProfile,
    num_docs: usize,
    rate: f64,
    num_requests: usize,
    timing: RetrievalTiming,
    seed: u64,
) -> SimOutcome {
    let corpus = Corpus::wikipedia_like(num_docs, seed);
    let trace = Trace::generate(
        profile,
        &corpus,
        rate,
        num_requests,
        cfg.retrieval.top_k,
        seed.wrapping_add(1),
    );
    SimServer::build(cfg, trace, num_docs, timing, seed.wrapping_add(2))
        .expect("sim server builds")
        .run()
}

/// Measure wall-clock time of `f` over `iters` iterations after `warmup`
/// warmup iterations; returns per-iteration seconds.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        s.add(t0.elapsed().as_secs_f64());
    }
    s
}

/// Adaptive microbenchmark: run `f` repeatedly for at least `min_time`
/// seconds (and at least 10 iterations), reporting per-iteration seconds.
pub fn time_for<F: FnMut()>(min_time: f64, mut f: F) -> Summary {
    // Warmup run also estimates a batch size.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let batch = ((0.01 / once).ceil() as usize).clamp(1, 1 << 20);
    let mut s = Summary::new();
    let start = Instant::now();
    while start.elapsed().as_secs_f64() < min_time || s.len() < 10 {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        s.add(t.elapsed().as_secs_f64() / batch as f64);
        if s.len() > 10_000 {
            break;
        }
    }
    s
}

/// A figure/table reproduction report: named columns, rows of values,
/// pretty printing and JSON output.
pub struct Report {
    name: String,
    title: String,
    columns: Vec<String>,
    rows: Vec<Vec<Json>>,
    notes: Vec<String>,
}

impl Report {
    pub fn new(name: &str, title: &str, columns: &[&str]) -> Self {
        Report {
            name: name.to_string(),
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, values: Vec<Json>) {
        assert_eq!(values.len(), self.columns.len(), "row arity");
        self.rows.push(values);
    }

    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    /// Print an aligned table to stdout.
    pub fn print(&self) {
        println!("\n== {} — {} ==", self.name, self.title);
        let mut widths: Vec<usize> =
            self.columns.iter().map(|c| c.len()).collect();
        let cells: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| r.iter().map(fmt_cell).collect())
            .collect();
        for row in &cells {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let header: Vec<String> = self
            .columns
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
            .collect();
        println!("{}", header.join("  "));
        println!("{}", "-".repeat(header.join("  ").len()));
        for row in &cells {
            let line: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect();
            println!("{}", line.join("  "));
        }
        for n in &self.notes {
            println!("note: {}", n);
        }
    }

    /// Write the report as JSON under `bench_out/`.
    pub fn save(&self) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all("bench_out")?;
        let path = std::path::PathBuf::from(format!("bench_out/{}.json", self.name));
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| {
                Json::Obj(
                    self.columns
                        .iter()
                        .cloned()
                        .zip(r.iter().cloned())
                        .collect(),
                )
            })
            .collect();
        let doc = Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("title", Json::str(self.title.clone())),
            ("rows", Json::Arr(rows)),
            (
                "notes",
                Json::Arr(self.notes.iter().cloned().map(Json::Str).collect()),
            ),
        ]);
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "{}", doc)?;
        Ok(path)
    }

    /// Print and save; panics on IO failure (bench context).
    pub fn finish(&self) {
        self.print();
        let path = self.save().expect("writing bench_out");
        println!("saved {}", path.display());
    }
}

fn fmt_cell(v: &Json) -> String {
    match v {
        Json::Str(s) => s.clone(),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e12 {
                format!("{}", *n as i64)
            } else if n.abs() >= 100.0 {
                format!("{:.1}", n)
            } else {
                format!("{:.3}", n)
            }
        }
        other => other.to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_returns_iters() {
        let s = time_it(2, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.len(), 5);
        assert!(s.min() >= 0.0);
    }

    #[test]
    fn report_roundtrip() {
        let mut r = Report::new("test_report", "unit test", &["x", "y"]);
        r.row(vec![Json::num(1.0), Json::str("a")]);
        r.row(vec![Json::num(2.0), Json::str("b")]);
        r.note("hello");
        let path = r.save().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let v = Json::parse(&text).unwrap();
        assert_eq!(v.get("rows").unwrap().as_arr().unwrap().len(), 2);
        std::fs::remove_file(path).ok();
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn report_rejects_bad_arity() {
        let mut r = Report::new("t", "t", &["a", "b"]);
        r.row(vec![Json::num(1.0)]);
    }
}
