//! `ragcache` — the serving binary.
//!
//! Subcommands:
//! - `serve`         start the PJRT-backed server on a TCP port
//! - `simulate`      run a paper-scale simulation and print metrics
//! - `info`          show models, GPUs, datasets and artifact status
//! - `stats-schema`  dump the metric registry schema (CI drift gate)

use anyhow::{anyhow, Context, Result};
use ragcache::cli::Args;
use ragcache::config::SystemConfig;
use ragcache::controller::real::{
    RealConfig, RealServer, SessionProtoBridge,
};
use ragcache::controller::{RetrievalTiming, SimServer};
use ragcache::embed::EmbeddingModel;
use ragcache::llm::models::{ALL_GPUS, ALL_MODELS};
use ragcache::llm::ByteTokenizer;
use ragcache::runtime::{ArtifactManifest, PjrtModel};
use ragcache::server::{proto, QueryHandler, Server, ServerOptions};
use ragcache::util::Rng;
use ragcache::vectordb::{FlatIndex, VectorIndex};
use ragcache::workload::{datasets::DatasetProfile, Corpus, Trace};
use std::path::Path;

const USAGE: &str = "\
ragcache <command> [options]

commands:
  serve      --port 7771 --model tiny-gqa --docs 256 [--artifacts DIR]
             [--workers N]     (N concurrent connection handlers, default 4)
             [--engines M]     (M engine-driver replicas, default 1)
             [--shards K]      (K knowledge-tree shards, default = engines)
             [--max-batch B]   (requests admitted per engine iteration,
                                one coalesced H2D burst each; default 8,
                                1 = unbatched)
             [--batch-tokens T] (compute-token budget per admitted batch,
                                default 16384)
             [--speculate on|off] (event-driven sessions: staged retrieval
                                on a thread pool overlapped with
                                speculative prefill, paper 5.3; default
                                off = blocking batched serving)
             [--retrieval-threads R] (staged-search pool size, default 2)
             [--stages S]      (stages per staged search, default 4)
             [--rebalance on|off] (demand-driven cross-shard tier
                                rebalancing: move GPU/host budget slices
                                from cold shards to hot ones; default
                                off = static 1/K split, bit-identical)
             [--rebalance-interval N] (engine iterations between slice
                                recomputes, default 32)
             [--chunk-cache on|off] (position-independent per-document
                                KV reuse beside the prefix tree;
                                default off = PR 5 path, bit-identical)
             [--boundary-tokens R] (tokens re-prefilled per chunk hit,
                                default 8)
             [--shed on|off]   (SLO admission control on the real path:
                                queue waits measured at reorder-queue
                                pop feed a delay EWMA — downgrade new
                                admissions to single-stage retrieval
                                under pressure, shed requests queued
                                past the TTFT SLO; default off =
                                bit-identical to the unshedded path)
             [--ttft-slo S]    (TTFT SLO seconds for --shed on and the
                                goodput/attainment stats, default 5.0)
             [--disk on|off]   (NVMe-backed third cache tier: host
                                evictions demote to disk through an
                                async staging thread and restage on
                                hit; default off = two tiers,
                                bit-identical)
             [--disk-gib G]    (disk-tier budget GiB, default 0.0625)
             [--cag off|auto]  (CAG corpus pinning: precompute and pin
                                the whole corpus KV when it fits the
                                pin budget, skipping retrieval;
                                requires --chunk-cache on; default off)
             [--cag-pin-gib G] (CAG pin budget GiB, default 0.00390625)
  simulate   --system ragcache|vllm|sglang --dataset mmlu --rate 0.8
             --requests 500 [--config FILE] [--model NAME] [--seed N]
             [--shards K] [--rebalance on|off] [--rebalance-interval N]
             [--chunk-cache on|off] [--boundary-tokens R]
             [--arrivals poisson|bursty|diurnal] (open-loop arrival
                                process; default poisson)
             [--tenants T]     (tenants with disjoint corpus slices and
                                per-tenant Zipf skew, default 1)
             [--shed on|off]   (admission control: downgrade speculation
                                under queueing pressure, shed requests
                                past the TTFT SLO; default off =
                                bit-identical to the pre-shedding path)
             [--ttft-slo S]    (TTFT SLO seconds for shedding and the
                                goodput/attainment report, default 5.0)
             [--docs N]        (corpus size in documents, default 300000)
             [--disk on|off]   (NVMe third cache tier behind host:
                                evictions demote down the ladder,
                                restages charged as ONE read burst per
                                admitted batch; default off = two
                                tiers, bit-identical)
             [--disk-gib G]    (disk-tier budget GiB, default 1024)
             [--disk-latency S] (per-read NVMe latency seconds,
                                default 100e-6)
             [--cag off|auto]  (per-tenant CAG corpus pinning: tenants
                                whose whole corpus KV fits the pin
                                budget skip retrieval entirely;
                                requires --chunk-cache on; default off)
             [--cag-pin-gib G] (CAG pin budget GiB, default 4)
  info       show models, GPUs, datasets, artifact status
  stats-schema  dump the declarative metric registry (wire names, merge
             semantics, tolerance classes, bench columns); ci.sh diffs
             the output against bench_baselines/stats_schema.txt
";

/// f64 GiB ↔ bytes for the `--*-gib` flags.
const GIB_F: f64 = (1u64 << 30) as f64;

fn main() {
    logger_init();
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() {
        eprint!("{USAGE}");
        std::process::exit(2);
    }
    let args = match Args::parse(&raw, &["verbose", "no-reorder", "no-spec"]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    let result = match cmd {
        "serve" => cmd_serve(&args),
        "simulate" => cmd_simulate(&args),
        "info" => cmd_info(),
        "stats-schema" => cmd_stats_schema(),
        _ => {
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn logger_init() {
    // Minimal logger: RUST_LOG=debug enables debug prints to stderr.
    struct L;
    impl log::Log for L {
        fn enabled(&self, m: &log::Metadata) -> bool {
            m.level() <= log::max_level()
        }
        fn log(&self, r: &log::Record) {
            if self.enabled(r.metadata()) {
                eprintln!("[{}] {}", r.level(), r.args());
            }
        }
        fn flush(&self) {}
    }
    static LOGGER: L = L;
    let _ = log::set_logger(&LOGGER);
    let level = std::env::var("RUST_LOG").unwrap_or_default();
    log::set_max_level(match level.as_str() {
        "debug" => log::LevelFilter::Debug,
        "trace" => log::LevelFilter::Trace,
        "" | "info" => log::LevelFilter::Info,
        _ => log::LevelFilter::Warn,
    });
}

/// The PJRT-backed handler for `ragcache serve`. All session plumbing
/// (ticket bookkeeping, wire conversion, stats) lives in the library's
/// [`SessionProtoBridge`] / [`RealServer::proto_stats`], shared with the
/// e2e example's handler.
pub struct RealHandler {
    server: RealServer,
    cfg: RealConfig,
    tok: ByteTokenizer,
    bridge: SessionProtoBridge,
}

impl RealHandler {
    pub fn new(server: RealServer, cfg: RealConfig) -> Self {
        RealHandler {
            server,
            cfg,
            tok: ByteTokenizer::new(),
            bridge: SessionProtoBridge::new(),
        }
    }
}

impl QueryHandler for RealHandler {
    fn query(
        &mut self,
        target_doc: u32,
        query: &str,
        max_new: usize,
    ) -> Result<proto::QueryResult> {
        self.query_batch(&[(target_doc, query.to_string(), max_new)])
            .pop()
            .expect("one result per query")
    }

    /// Batched entry point: all members admit first, coalescing their
    /// cache-hit transfers into one H2D burst
    /// (`RealServer::serve_batch`), then prefill/decode in turn. With
    /// `--speculate on` this is the blocking wrapper that drives the
    /// members through the session lifecycle instead.
    fn query_batch(
        &mut self,
        batch: &[(u32, String, usize)],
    ) -> Vec<Result<proto::QueryResult>> {
        self.server.serve_proto_batch(batch, &self.tok, &self.cfg)
    }

    /// Wait-aware batched entry: the engine loop's measured queue waits
    /// feed the `--shed on` admission-control ladder (inert — identical
    /// to `query_batch` — with `--shed off`).
    fn query_batch_timed(
        &mut self,
        batch: &[(u32, String, usize)],
        waits: &[f64],
    ) -> Vec<Result<proto::QueryResult>> {
        self.server
            .serve_proto_batch_timed(batch, waits, &self.tok, &self.cfg)
    }

    /// Non-blocking entry (the `--speculate on` engine loop): start a
    /// session whose staged retrieval runs on the server's thread pool;
    /// the result streams back through `poll_sessions`.
    fn submit_session(
        &mut self,
        ticket: u64,
        target_doc: u32,
        query: &str,
        max_new: usize,
    ) -> Option<Result<proto::QueryResult>> {
        self.bridge.submit(
            &mut self.server,
            ticket,
            target_doc,
            query,
            max_new,
            &self.tok,
            &self.cfg,
        )
    }

    /// Wait-aware session submit: a request queued past the TTFT SLO is
    /// shed here (`Some(Err(..))`) without opening a session.
    fn submit_session_timed(
        &mut self,
        ticket: u64,
        target_doc: u32,
        query: &str,
        max_new: usize,
        wait: f64,
    ) -> Option<Result<proto::QueryResult>> {
        self.bridge.submit_timed(
            &mut self.server,
            ticket,
            target_doc,
            query,
            max_new,
            wait,
            &self.tok,
            &self.cfg,
        )
    }

    fn poll_sessions(
        &mut self,
        timeout: std::time::Duration,
    ) -> Vec<ragcache::server::SessionDone> {
        self.bridge
            .poll(&mut self.server, timeout, &self.tok, &self.cfg)
            .into_iter()
            .map(|(ticket, result)| ragcache::server::SessionDone {
                ticket,
                result,
            })
            .collect()
    }

    fn sessions_in_flight(&self) -> usize {
        self.server.in_flight_sessions()
    }

    fn stats(&self) -> proto::StatsResult {
        self.server.proto_stats()
    }
}

/// Per-engine corpus assets (vector index, embeddings, document token
/// ids). Deterministic from `(num_docs, seed)`, so every engine replica
/// rebuilds the identical knowledge base while the knowledge-tree cache
/// itself is shared through the [`ragcache::controller::ShardedCacheService`].
pub struct CorpusParts {
    pub index: Box<dyn VectorIndex>,
    pub em: EmbeddingModel,
    pub doc_tokens: Vec<Vec<i32>>,
}

/// Build the synthetic tiny corpus + embedding index.
pub fn build_corpus_parts(num_docs: usize, seed: u64) -> CorpusParts {
    let corpus = Corpus::tiny(num_docs, seed);
    let mut rng = Rng::new(seed);
    // Document token ids: random bytes of the corpus-assigned length.
    let doc_tokens: Vec<Vec<i32>> = (0..num_docs)
        .map(|d| {
            (0..corpus.tokens(d as u32))
                .map(|_| rng.index(256) as i32)
                .collect()
        })
        .collect();
    let dim = 16;
    let em = EmbeddingModel::new(dim, seed ^ 0xE);
    let vecs: Vec<Vec<f32>> =
        (0..num_docs as u32).map(|d| em.document(d)).collect();
    let index: Box<dyn VectorIndex> = Box::new(FlatIndex::build(dim, &vecs));
    CorpusParts {
        index,
        em,
        doc_tokens,
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let port: u16 = args.get_parse_or("port", 7771).map_err(|e| anyhow!(e))?;
    let model = args.get_or("model", "tiny-gqa").to_string();
    let docs: usize = args.get_parse_or("docs", 256).map_err(|e| anyhow!(e))?;
    let workers: usize =
        args.get_parse_or("workers", 4).map_err(|e| anyhow!(e))?;
    let engines: usize =
        args.get_parse_or("engines", 1).map_err(|e| anyhow!(e))?;
    let shards: usize = args
        .get_parse_or("shards", engines.max(1))
        .map_err(|e| anyhow!(e))?;
    let default_opts = ServerOptions::default();
    let max_batch: usize = args
        .get_parse_or("max-batch", default_opts.max_batch)
        .map_err(|e| anyhow!(e))?;
    let batch_tokens: usize = args
        .get_parse_or("batch-tokens", default_opts.batch_tokens)
        .map_err(|e| anyhow!(e))?;
    if max_batch == 0 {
        return Err(anyhow!("--max-batch must be >= 1"));
    }
    if batch_tokens == 0 {
        return Err(anyhow!("--batch-tokens must be >= 1"));
    }
    let speculate = match args.get_or("speculate", "off") {
        "on" => true,
        "off" => false,
        other => {
            return Err(anyhow!(
                "--speculate expects on|off, got '{other}'"
            ))
        }
    };
    let retrieval_threads: usize = args
        .get_parse_or("retrieval-threads", 2)
        .map_err(|e| anyhow!(e))?;
    let stages: usize =
        args.get_parse_or("stages", 4).map_err(|e| anyhow!(e))?;
    if retrieval_threads == 0 {
        return Err(anyhow!("--retrieval-threads must be >= 1"));
    }
    if stages == 0 {
        return Err(anyhow!("--stages must be >= 1"));
    }
    let rebalance = match args.get_or("rebalance", "off") {
        "on" => true,
        "off" => false,
        other => {
            return Err(anyhow!(
                "--rebalance expects on|off, got '{other}'"
            ))
        }
    };
    let rebalance_interval: u64 = args
        .get_parse_or("rebalance-interval", 32)
        .map_err(|e| anyhow!(e))?;
    if rebalance_interval == 0 {
        return Err(anyhow!("--rebalance-interval must be >= 1"));
    }
    let chunk_cache = match args.get_or("chunk-cache", "off") {
        "on" => true,
        "off" => false,
        other => {
            return Err(anyhow!(
                "--chunk-cache expects on|off, got '{other}'"
            ))
        }
    };
    let boundary_tokens: usize = args
        .get_parse_or("boundary-tokens", 8)
        .map_err(|e| anyhow!(e))?;
    if chunk_cache && boundary_tokens == 0 {
        return Err(anyhow!(
            "--boundary-tokens must be >= 1 with --chunk-cache on"
        ));
    }
    let shed = match args.get_or("shed", "off") {
        "on" => true,
        "off" => false,
        other => {
            return Err(anyhow!("--shed expects on|off, got '{other}'"))
        }
    };
    let disk = match args.get_or("disk", "off") {
        "on" => true,
        "off" => false,
        other => {
            return Err(anyhow!("--disk expects on|off, got '{other}'"))
        }
    };
    let disk_gib: f64 = args
        .get_parse_or(
            "disk-gib",
            RealConfig::default().disk_cache_bytes as f64 / GIB_F,
        )
        .map_err(|e| anyhow!(e))?;
    if disk && !(disk_gib > 0.0) {
        return Err(anyhow!(
            "--disk-gib must be > 0 with --disk on, got {disk_gib}"
        ));
    }
    let cag = match args.get_or("cag", "off") {
        "auto" => true,
        "off" => false,
        other => {
            return Err(anyhow!("--cag expects off|auto, got '{other}'"))
        }
    };
    let cag_pin_gib: f64 = args
        .get_parse_or(
            "cag-pin-gib",
            RealConfig::default().cag_pin_bytes as f64 / GIB_F,
        )
        .map_err(|e| anyhow!(e))?;
    if cag && !chunk_cache {
        return Err(anyhow!(
            "--cag auto requires --chunk-cache on (corpus pins are \
             position-independent chunk entries)"
        ));
    }
    let default_slo = RealConfig::default().ttft_slo_s;
    let ttft_slo_s: f64 = args
        .get_parse_or("ttft-slo", default_slo)
        .map_err(|e| anyhow!(e))?;
    if shed && !(ttft_slo_s > 0.0) {
        return Err(anyhow!(
            "--ttft-slo must be > 0 with --shed on, got {ttft_slo_s}"
        ));
    }
    if shards < engines.max(1) {
        // Engines drain shards routed shard % engines: with fewer
        // shards than engines the surplus engines would each load a
        // full PJRT model and then never receive a job.
        return Err(anyhow!(
            "--shards ({shards}) must be >= --engines ({engines}); \
             extra engines would sit idle"
        ));
    }
    let artifacts = args.get_or("artifacts", "artifacts").to_string();
    let artifacts_path = std::path::PathBuf::from(&artifacts);
    if !artifacts_path.join("manifest.json").exists() {
        return Err(anyhow!(
            "artifacts missing at {artifacts} (run `make artifacts`)"
        ));
    }
    let corpus_seed = 42u64;
    let cfg = RealConfig {
        speculate,
        stages,
        retrieval_threads,
        spec_pool: max_batch,
        chunk_cache,
        boundary_tokens,
        shed,
        ttft_slo_s,
        disk,
        disk_cache_bytes: (disk_gib * GIB_F) as u64,
        cag,
        cag_pin_bytes: (cag_pin_gib * GIB_F) as u64,
        ..RealConfig::default()
    };
    // One sharded cache service shared by every engine replica, the
    // priority estimator and the affinity router: each shard has its own
    // lock and tier-budget slice, so M engines admit in parallel.
    let manifest = ArtifactManifest::load(&artifacts_path)
        .context("loading artifact manifest")?;
    let kv_floats = manifest.model(&model)?.arch.kv_floats_per_token();
    let mut cache =
        RealServer::build_sharded_cache(kv_floats, &cfg, shards);
    if rebalance {
        // Installed before any clone is taken, so every engine replica,
        // the estimator and the router share ONE rebalancer state; each
        // engine iteration / session poll ticks it.
        cache.enable_rebalancing(
            ragcache::controller::RebalanceConfig {
                interval: rebalance_interval,
                ..ragcache::controller::RebalanceConfig::default()
            },
        );
    }

    // Cache-aware §5.2 priority estimator over the same shared cache
    // service the engines admit against: α from the live tree, β
    // approximated as top_k docs of this corpus minus the cached prefix
    // (an estimate is all the reorder priority needs).
    let est_cache = cache.clone();
    let corpus = Corpus::tiny(docs, corpus_seed);
    let doc_lens: Vec<usize> =
        (0..docs).map(|d| corpus.tokens(d as u32)).collect();
    let mean_len =
        (doc_lens.iter().sum::<usize>() / doc_lens.len().max(1)).max(1);
    let top_k = cfg.top_k;
    let estimator: ragcache::server::PriorityEstimator =
        std::sync::Arc::new(move |req| match req {
            proto::Request::Query { target_doc, .. } => {
                // α counts both the prefix match and any chunk-cache
                // entry for the target doc (reused = span − boundary);
                // with `--chunk-cache off` the reused term is 0 and
                // this is exactly the PR 5 estimator.
                let (m, reused) =
                    est_cache.lookup_with_chunks(&[*target_doc]);
                let cached = m.cached_tokens + reused;
                let total = doc_lens
                    .get(*target_doc as usize)
                    .copied()
                    .unwrap_or(mean_len)
                    + mean_len * top_k.saturating_sub(1);
                (cached, total.saturating_sub(cached).max(1))
            }
            _ => (0, 1),
        });
    // Engine affinity = tree shard of the query's TARGET document. The
    // tree itself shards by the first *retrieved* doc, which under
    // query noise can differ — routing is an affinity hint (per-shard
    // locks keep cross-engine admissions correct either way), and the
    // target is the best signal available before retrieval runs on the
    // engine.
    let route_cache = cache.clone();
    let router: ragcache::server::ShardFn =
        std::sync::Arc::new(move |req| match req {
            proto::Request::Query { target_doc, .. } => {
                route_cache.shard_of_doc(*target_doc)
            }
            _ => 0,
        });

    let opts = ServerOptions {
        workers,
        engines,
        max_batch,
        batch_tokens,
        speculate,
        estimator: Some(estimator),
        router: Some(router),
        ..ServerOptions::default()
    };
    let engine_cache = cache.clone();
    let handler_cfg = cfg.clone();
    let server = Server::spawn_sharded(port, opts, move |engine| {
        // Only the PJRT model loads here (its handles are not `Send`);
        // each engine replica carries its own model + corpus assets and
        // shares the sharded knowledge-tree cache.
        let manifest = ArtifactManifest::load(&artifacts_path)?;
        let pjrt = PjrtModel::load(manifest.model(&model)?)
            .context("loading PJRT model")?;
        let parts = build_corpus_parts(docs, corpus_seed);
        let doc_lens: Vec<usize> =
            parts.doc_tokens.iter().map(|t| t.len()).collect();
        let mut server = RealServer::with_cache(
            pjrt,
            parts.index,
            parts.em,
            parts.doc_tokens,
            engine_cache.clone(),
        )
        .context(format!("assembling engine {engine}"))?;
        if handler_cfg.cag {
            // The serve path has one tenant owning the whole corpus;
            // prestaging is idempotent across engine replicas (the
            // shared cache reports already-present entries), so every
            // engine arms its own policy against the same pins.
            let corpora = vec![ragcache::workload::TenantCorpus {
                tenant: 0,
                doc_base: 0,
                doc_tokens: doc_lens,
            }];
            server
                .enable_cag(&corpora, &handler_cfg)
                .context(format!("CAG prestage on engine {engine}"))?;
        }
        Ok(RealHandler::new(server, handler_cfg.clone()))
    })?;
    println!(
        "ragcache serving on {} ({docs} docs, {workers} connection \
         workers, {engines} engines, {shards} tree shards, \
         {max_batch}-request admission batches, speculation {}, \
         rebalancing {}, chunk cache {}, admission control {}, \
         disk tier {}, cag {})",
        server.addr,
        if speculate { "on" } else { "off" },
        if rebalance { "on" } else { "off" },
        if chunk_cache { "on" } else { "off" },
        if shed {
            format!("on (TTFT SLO {ttft_slo_s}s)")
        } else {
            "off".to_string()
        },
        if disk {
            format!("on ({disk_gib} GiB)")
        } else {
            "off".to_string()
        },
        if cag { "auto" } else { "off" }
    );
    println!("protocol: newline-delimited JSON; ops: query/stats/shutdown");
    // Block until the acceptor thread exits (shutdown op).
    server.join();
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => SystemConfig::from_file(Path::new(path))?,
        None => SystemConfig::default(),
    };
    if let Some(system) = args.get("system") {
        cfg.kind = ragcache::config::SystemKindField(
            ragcache::config::SystemKind::parse(system)?,
        );
    }
    if let Some(model) = args.get("model") {
        cfg.engine.model = model.to_string();
    }
    if let Some(dataset) = args.get("dataset") {
        cfg.workload.dataset = dataset.to_string();
    }
    cfg.workload.rate = args
        .get_parse_or("rate", cfg.workload.rate)
        .map_err(|e| anyhow!(e))?;
    cfg.workload.num_requests = args
        .get_parse_or("requests", cfg.workload.num_requests)
        .map_err(|e| anyhow!(e))?;
    let seed: u64 = args.get_parse_or("seed", 42).map_err(|e| anyhow!(e))?;
    if args.flag("no-reorder") {
        cfg.sched.reorder = false;
    }
    if args.flag("no-spec") {
        cfg.spec.enabled = false;
    }
    cfg.cache.shards = args
        .get_parse_or("shards", cfg.cache.shards)
        .map_err(|e| anyhow!(e))?;
    if let Some(r) = args.get("rebalance") {
        cfg.cache.rebalance = match r {
            "on" => true,
            "off" => false,
            other => {
                return Err(anyhow!(
                    "--rebalance expects on|off, got '{other}'"
                ))
            }
        };
    }
    cfg.cache.rebalance_interval = args
        .get_parse_or("rebalance-interval", cfg.cache.rebalance_interval)
        .map_err(|e| anyhow!(e))?;
    if let Some(c) = args.get("chunk-cache") {
        cfg.cache.chunk_cache = match c {
            "on" => true,
            "off" => false,
            other => {
                return Err(anyhow!(
                    "--chunk-cache expects on|off, got '{other}'"
                ))
            }
        };
    }
    cfg.cache.boundary_tokens = args
        .get_parse_or("boundary-tokens", cfg.cache.boundary_tokens)
        .map_err(|e| anyhow!(e))?;
    if let Some(a) = args.get("arrivals") {
        cfg.workload.arrivals = a.to_string();
    }
    cfg.workload.tenants = args
        .get_parse_or("tenants", cfg.workload.tenants)
        .map_err(|e| anyhow!(e))?;
    if let Some(s) = args.get("shed") {
        cfg.shed.enabled = match s {
            "on" => true,
            "off" => false,
            other => {
                return Err(anyhow!("--shed expects on|off, got '{other}'"))
            }
        };
    }
    cfg.shed.ttft_slo_s = args
        .get_parse_or("ttft-slo", cfg.shed.ttft_slo_s)
        .map_err(|e| anyhow!(e))?;
    cfg.workload.num_docs = args
        .get_parse_or("docs", cfg.workload.num_docs)
        .map_err(|e| anyhow!(e))?;
    if let Some(d) = args.get("disk") {
        cfg.cache.disk = match d {
            "on" => true,
            "off" => false,
            other => {
                return Err(anyhow!(
                    "--disk expects on|off, got '{other}'"
                ))
            }
        };
    }
    let disk_gib: f64 = args
        .get_parse_or("disk-gib", cfg.cache.disk_bytes as f64 / GIB_F)
        .map_err(|e| anyhow!(e))?;
    cfg.cache.disk_bytes = (disk_gib * GIB_F) as u64;
    cfg.cache.disk_latency_s = args
        .get_parse_or("disk-latency", cfg.cache.disk_latency_s)
        .map_err(|e| anyhow!(e))?;
    if let Some(c) = args.get("cag") {
        cfg.cache.cag = match c {
            "auto" => true,
            "off" => false,
            other => {
                return Err(anyhow!(
                    "--cag expects off|auto, got '{other}'"
                ))
            }
        };
    }
    let pin_gib: f64 = args
        .get_parse_or(
            "cag-pin-gib",
            cfg.cache.cag_pin_bytes as f64 / GIB_F,
        )
        .map_err(|e| anyhow!(e))?;
    cfg.cache.cag_pin_bytes = (pin_gib * GIB_F) as u64;
    cfg.validate()?;

    let profile = DatasetProfile::lookup(&cfg.workload.dataset)?;
    let corpus = Corpus::wikipedia_like(cfg.workload.num_docs, seed);
    let trace_opts = ragcache::workload::TraceOptions {
        top_k: cfg.retrieval.top_k,
        arrivals: ragcache::workload::ArrivalProcess::parse(
            &cfg.workload.arrivals,
        )?,
        tenants: cfg.workload.tenants,
        ..ragcache::workload::TraceOptions::default()
    };
    let trace = Trace::generate_open_loop(
        profile,
        &corpus,
        cfg.workload.rate,
        cfg.workload.num_requests,
        &trace_opts,
        seed,
    );
    let mut server = SimServer::build(
        &cfg,
        trace,
        cfg.workload.num_docs,
        RetrievalTiming::default(),
        seed,
    )?;
    if cfg.cache.cag {
        let corpora =
            ragcache::workload::tenant_corpora(&corpus, &trace_opts);
        server.enable_cag(&corpora, cfg.cache.cag_pin_bytes);
    }
    let out = server.run();
    let mut ttft = out.recorder.ttft();
    println!(
        "system={} model={} dataset={} rate={} requests={} arrivals={} \
         tenants={} shed={}",
        cfg.kind.name(),
        cfg.engine.model,
        cfg.workload.dataset,
        cfg.workload.rate,
        cfg.workload.num_requests,
        cfg.workload.arrivals,
        cfg.workload.tenants,
        if cfg.shed.enabled { "on" } else { "off" },
    );
    println!(
        "TTFT mean {:.3}s p50 {:.3}s p99 {:.3}s p99.9 {:.3}s | \
         hit-rate {:.1}% | throughput {:.2} req/s | sched {:.3}ms",
        ttft.mean(),
        ttft.median(),
        ttft.p99(),
        ttft.p999(),
        out.recorder.hit_rate() * 100.0,
        out.recorder.throughput(),
        out.mean_sched_time * 1e3,
    );
    let slo = cfg.shed.ttft_slo_s;
    println!(
        "SLO ({slo:.2}s TTFT): goodput {:.2} req/s, attainment {:.1}%, \
         {} shed, {} downgraded",
        out.recorder.goodput(slo),
        out.recorder.slo_attainment(slo) * 100.0,
        out.shed_requests,
        out.downgraded_requests,
    );
    if cfg.workload.tenants > 1 {
        for t in out.recorder.per_tenant(slo) {
            println!(
                "tenant {}: {} requests, {} completed, {} shed, \
                 {} downgraded, {} in-SLO, mean TTFT {:.3}s",
                t.tenant,
                t.requests,
                t.completed,
                t.shed,
                t.downgraded,
                t.slo_ok,
                t.mean_ttft(),
            );
        }
    }
    if let Some(c) = out.tree_counters {
        println!(
            "tree: {} inserts, {} gpu evictions ({} zero-copy), {} host \
             evictions, {} swapped out",
            c.inserts,
            c.gpu_evictions,
            c.zero_copy_evictions,
            c.host_evictions,
            ragcache::util::fmt_bytes(c.swap_out_bytes),
        );
    }
    println!(
        "speculation: {} started, {} wasted, {} promoted",
        out.spec_started, out.spec_wasted, out.spec_promoted
    );
    if cfg.cache.chunk_cache {
        println!(
            "chunk cache: {} hits, {} reused, {} boundary tokens \
             recomputed",
            out.chunk_hits(),
            ragcache::util::fmt_bytes(out.chunk_hit_bytes()),
            out.boundary_recompute_tokens(),
        );
    }
    if cfg.cache.rebalance {
        let rb = out.rebalance;
        println!(
            "rebalancing: {} recomputes, {} moves, {} gpu + {} host \
             capacity moved, {} refused shrinks",
            rb.recomputes,
            rb.moves,
            ragcache::util::fmt_bytes(rb.gpu_bytes_moved),
            ragcache::util::fmt_bytes(rb.host_bytes_moved),
            rb.refused_shrinks,
        );
    }
    if cfg.cache.disk {
        println!(
            "disk tier: {} spills ({} staged down), {} restage hits \
             ({} read back)",
            out.disk_spills(),
            ragcache::util::fmt_bytes(out.disk_spill_bytes()),
            out.disk_restage_hits(),
            ragcache::util::fmt_bytes(out.disk_restage_bytes()),
        );
    }
    if cfg.cache.cag {
        let cag_tenants = out
            .tenant_modes
            .iter()
            .filter(|(_, m)| {
                *m == ragcache::controller::TenantMode::Cag
            })
            .count();
        println!(
            "cag: {} corpus KV pinned across {} of {} tenants",
            ragcache::util::fmt_bytes(out.cag_pinned_bytes),
            cag_tenants,
            out.tenant_modes.len(),
        );
        for (t, m) in &out.tenant_modes {
            println!("  tenant {t}: {}", m.as_str());
        }
    }
    Ok(())
}

/// `stats-schema`: print the metric registry's generated schema. ci.sh
/// diffs this against the committed `bench_baselines/stats_schema.txt`,
/// so a stat added or removed without regenerating the snapshot fails
/// CI loudly (the schema analogue of the bench_diff column-set rule).
fn cmd_stats_schema() -> Result<()> {
    use ragcache::metrics::registry::{schema_dump, Registry};
    print!("{}", schema_dump(&Registry::standard()));
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("models (paper Table 1 + tiny PJRT variants):");
    for m in ALL_MODELS {
        println!(
            "  {:<14} layers={:<3} q/kv={}/{:<3} kv={}/token params={}",
            m.name,
            m.n_layers,
            m.n_q_heads,
            m.n_kv_heads,
            ragcache::util::fmt_bytes(m.kv_bytes_per_token as u64),
            ragcache::util::fmt_bytes(m.params_bytes),
        );
    }
    println!("gpus:");
    for g in ALL_GPUS {
        println!(
            "  {:<8} {:.0} TFLOPS, {:.0} GB/s, {}",
            g.name,
            g.peak_flops / 1e12,
            g.hbm_bps / 1e9,
            ragcache::util::fmt_bytes(g.memory_bytes),
        );
    }
    println!("datasets: mmlu, nq, hotpotqa, triviaqa");
    let art = Path::new("artifacts/manifest.json");
    println!(
        "artifacts: {}",
        if art.exists() {
            "built"
        } else {
            "missing (run `make artifacts`)"
        }
    );
    Ok(())
}
