//! Dynamic speculative pipelining (paper §5.3, Algorithm 2).
//!
//! Vector search exposes intermediate top-k candidates per stage; the
//! controller may start LLM prefill speculatively on a candidate set
//! before the search finishes. On a stage whose candidates differ from
//! the running speculation, the old speculation is terminated (after its
//! current iteration) and — if the engine's prefill pool has room
//! (`pool.size < max_prefill_bs`) — a new one starts. Theorem 5.1: with
//! an empty pool, speculating is never worse; with a non-empty pool,
//! defer unless final.

use crate::tree::DocId;

/// Decision for one retrieval stage.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SpecAction {
    /// Start a new speculative generation on these docs (and terminate
    /// the previous speculation if `terminate_prev`).
    Start { terminate_prev: bool },
    /// Candidates unchanged — keep the running speculation.
    Keep,
    /// Candidates changed but the pool is full: terminate the stale
    /// speculation and wait (defer) — Algorithm 2 lines 6–10.
    Defer { terminate_prev: bool },
}

/// Per-request speculative pipelining state machine.
#[derive(Debug, Clone, Default)]
pub struct SpecState {
    /// Candidate docs of the running/last speculation.
    current: Option<Vec<DocId>>,
    /// Whether a speculative generation is live in the engine.
    active: bool,
    /// Monotone generation counter (distinguishes speculation attempts).
    pub generation: u64,
    /// Counters for the ablation (Table 3 / Fig. 19): generations
    /// started, speculations terminated (their work discarded), and
    /// speculations confirmed by the final stage (their work delivered).
    pub started: u64,
    pub wasted: u64,
    pub promoted: u64,
}

impl SpecState {
    pub fn new() -> Self {
        SpecState::default()
    }

    pub fn is_active(&self) -> bool {
        self.active
    }

    pub fn current_docs(&self) -> Option<&[DocId]> {
        self.current.as_deref()
    }

    /// Algorithm 2 body for one stage tick.
    ///
    /// `docs` is the stage's candidate top-k; `pool_len` the engine's
    /// waiting-prefill count; `max_prefill_bs` the admission bound;
    /// `is_final` marks the search's completion stage (final results are
    /// always admitted — they are no longer speculative).
    pub fn on_stage(
        &mut self,
        docs: &[DocId],
        pool_len: usize,
        max_prefill_bs: usize,
        is_final: bool,
    ) -> SpecAction {
        let unchanged = self
            .current
            .as_deref()
            .map(|c| c == docs)
            .unwrap_or(false);
        if unchanged {
            if self.active {
                // Same docs: the running speculation (or admitted final)
                // already covers this request. On the completion stage
                // this is the paper's promotion — the speculative work
                // graduates to the delivered generation.
                if is_final {
                    self.promoted += 1;
                }
                return SpecAction::Keep;
            }
            // Previously deferred; admit if final or room appeared.
            if is_final || pool_len < max_prefill_bs {
                self.active = true;
                self.generation += 1;
                self.started += 1;
                return SpecAction::Start {
                    terminate_prev: false,
                };
            }
            return SpecAction::Defer {
                terminate_prev: false,
            };
        }

        // Candidates changed.
        let terminate_prev = self.active;
        if terminate_prev {
            self.wasted += 1;
        }
        self.current = Some(docs.to_vec());
        if is_final || pool_len < max_prefill_bs {
            self.active = true;
            self.generation += 1;
            self.started += 1;
            SpecAction::Start { terminate_prev }
        } else {
            self.active = false;
            SpecAction::Defer { terminate_prev }
        }
    }

    /// The live speculation died outside Algorithm 2 — its prefill
    /// failed before producing a usable artifact. Count it wasted and
    /// clear `active`, so a later stage restarts instead of believing a
    /// speculation still covers this request.
    pub fn cancel_active(&mut self) {
        if self.active {
            self.active = false;
            self.wasted += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_pool_starts_immediately() {
        // Theorem 5.1 cases (1)/(4): empty pool => speculate.
        let mut s = SpecState::new();
        let a = s.on_stage(&[1, 3], 0, 4, false);
        assert_eq!(
            a,
            SpecAction::Start {
                terminate_prev: false
            }
        );
        assert!(s.is_active());
        assert_eq!(s.started, 1);
    }

    #[test]
    fn unchanged_docs_keep_running() {
        // Paper Fig. 11 stage 3: same docs => keep processing.
        let mut s = SpecState::new();
        s.on_stage(&[1, 2], 0, 4, false);
        let a = s.on_stage(&[1, 2], 3, 4, false);
        assert_eq!(a, SpecAction::Keep);
        assert_eq!(s.started, 1, "no duplicate start");
    }

    #[test]
    fn changed_docs_terminate_and_restart() {
        // Fig. 11 stage 2: [D1,D3] -> [D1,D2] terminates + restarts.
        let mut s = SpecState::new();
        s.on_stage(&[1, 3], 0, 4, false);
        let a = s.on_stage(&[1, 2], 0, 4, false);
        assert_eq!(
            a,
            SpecAction::Start {
                terminate_prev: true
            }
        );
        assert_eq!(s.wasted, 1);
        assert_eq!(s.started, 2);
    }

    #[test]
    fn full_pool_defers_non_final() {
        // Theorem 5.1 case (2): non-empty pool + non-final => defer.
        let mut s = SpecState::new();
        let a = s.on_stage(&[1, 2], 4, 4, false);
        assert_eq!(
            a,
            SpecAction::Defer {
                terminate_prev: false
            }
        );
        assert!(!s.is_active());
    }

    #[test]
    fn final_results_always_admitted() {
        // Theorem 5.1 case (3): final results enter even with a full
        // pool.
        let mut s = SpecState::new();
        s.on_stage(&[1, 2], 4, 4, false); // deferred
        let a = s.on_stage(&[1, 2], 4, 4, true);
        assert_eq!(
            a,
            SpecAction::Start {
                terminate_prev: false
            }
        );
        assert!(s.is_active());
    }

    #[test]
    fn final_matching_speculation_needs_no_restart() {
        // Fig. 11 final stage: search confirms the running speculation.
        let mut s = SpecState::new();
        s.on_stage(&[1, 2], 0, 4, false);
        let a = s.on_stage(&[1, 2], 2, 4, true);
        assert_eq!(a, SpecAction::Keep);
        assert_eq!(s.started, 1);
        assert_eq!(s.wasted, 0);
        assert_eq!(s.promoted, 1, "the confirmed speculation is promoted");
    }

    #[test]
    fn promoted_counts_only_final_confirmations() {
        let mut s = SpecState::new();
        s.on_stage(&[1, 2], 0, 4, false);
        s.on_stage(&[1, 2], 0, 4, false); // Keep, non-final: no promotion
        assert_eq!(s.promoted, 0);
        s.on_stage(&[1, 2], 0, 4, true);
        assert_eq!(s.promoted, 1);
        // A final restart (mismatched docs) is a re-generation, not a
        // promotion.
        let mut r = SpecState::new();
        r.on_stage(&[1, 3], 0, 4, false);
        r.on_stage(&[1, 2], 0, 4, true);
        assert_eq!(r.promoted, 0);
        assert_eq!(r.wasted, 1);
    }

    #[test]
    fn cancel_active_counts_wasted_and_allows_restart() {
        let mut s = SpecState::new();
        s.on_stage(&[4, 5], 0, 4, false);
        assert!(s.is_active());
        s.cancel_active();
        assert!(!s.is_active());
        assert_eq!(s.wasted, 1);
        s.cancel_active(); // idempotent on an inactive state
        assert_eq!(s.wasted, 1);
        // Unchanged docs on a later stage restart the speculation
        // instead of believing one is still running.
        let a = s.on_stage(&[4, 5], 0, 4, false);
        assert_eq!(
            a,
            SpecAction::Start {
                terminate_prev: false
            }
        );
        assert_eq!(s.started, 2);
    }

    #[test]
    fn final_mismatch_regenerates() {
        // "Otherwise, the LLM engine performs re-generation."
        let mut s = SpecState::new();
        s.on_stage(&[1, 3], 0, 4, false);
        let a = s.on_stage(&[1, 2], 1, 4, true);
        assert_eq!(
            a,
            SpecAction::Start {
                terminate_prev: true
            }
        );
        assert_eq!(s.wasted, 1);
    }

    #[test]
    fn deferred_then_room_appears() {
        let mut s = SpecState::new();
        s.on_stage(&[5, 6], 4, 4, false); // defer
        let a = s.on_stage(&[5, 6], 1, 4, false); // room now
        assert_eq!(
            a,
            SpecAction::Start {
                terminate_prev: false
            }
        );
    }
}
