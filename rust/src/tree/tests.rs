//! Knowledge-tree unit and property tests.

use super::*;
use crate::config::PolicyKind;
use crate::policy::{make_policy, AccessCtx};
use crate::prop_assert;
use crate::testing::{check_with, PropConfig};
use crate::util::Rng;

fn page() -> PageSpec {
    PageSpec {
        block_tokens: 16,
        kv_bytes_per_token: 64,
    }
}

fn tree(gpu_tokens: usize, host_tokens: usize) -> KnowledgeTree {
    let p = page();
    KnowledgeTree::new(
        p.bytes(gpu_tokens),
        p.bytes(host_tokens),
        p,
        make_policy(PolicyKind::Pgdsf),
        true,
        0,
    )
}

fn access(tokens: usize, now: f64) -> AccessCtx {
    AccessCtx {
        alpha: 0,
        beta: tokens,
        estimated_time: tokens as f64 * 1e-3,
        was_cached: false,
        now,
        tokens,
    }
}

/// Insert the doc sequence as a path from root, touching stats.
fn insert_path(t: &mut KnowledgeTree, docs: &[DocId], tokens: usize, now: f64) -> Vec<NodeId> {
    let mut parent = t.root();
    let mut ids = Vec::new();
    for &d in docs {
        let (_, id) = t.insert_child(parent, d, tokens, None);
        let id = id.expect("fits");
        t.on_access(id, &access(tokens, now));
        ids.push(id);
        parent = id;
    }
    ids
}

#[test]
fn lookup_walks_prefix_and_stops_at_miss() {
    let mut t = tree(1000, 1000);
    insert_path(&mut t, &[1, 2, 3], 16, 0.0);
    let m = t.lookup(&[1, 2, 9]);
    assert_eq!(m.matched_docs, 2);
    assert_eq!(m.cached_tokens, 32);
    assert_eq!(m.gpu_tokens, 32);
    let m2 = t.lookup(&[9, 1, 2]);
    assert_eq!(m2.matched_docs, 0);
    t.check_invariants();
}

#[test]
fn order_sensitivity_distinct_nodes() {
    // [D1,D2] and [D2,D1] must occupy different nodes (§5.1).
    let mut t = tree(1000, 1000);
    insert_path(&mut t, &[1, 2], 16, 0.0);
    insert_path(&mut t, &[2, 1], 16, 0.0);
    // Root has two children (doc 1 and doc 2), each with one child.
    assert_eq!(t.node_count(), 5); // root + 4
    assert_eq!(t.lookup(&[1, 2]).matched_docs, 2);
    assert_eq!(t.lookup(&[2, 1]).matched_docs, 2);
    t.check_invariants();
}

#[test]
fn eviction_swaps_leaf_to_host() {
    // GPU fits 2 docs of 16 tokens; inserting a 3rd evicts one leaf.
    let mut t = tree(32, 1000);
    insert_path(&mut t, &[1], 16, 0.0);
    insert_path(&mut t, &[2], 16, 1.0);
    insert_path(&mut t, &[3], 16, 2.0);
    let tiers: Vec<_> = [1u32, 2, 3]
        .iter()
        .map(|&d| t.node_tier(t.lookup(&[d]).path[0]))
        .collect();
    let gpu_count = tiers.iter().filter(|t| **t == Some(Tier::Gpu)).count();
    let host_count = tiers.iter().filter(|t| **t == Some(Tier::Host)).count();
    assert_eq!(gpu_count, 2);
    assert_eq!(host_count, 1);
    assert_eq!(t.counters().gpu_evictions, 1);
    assert_eq!(t.counters().swap_out_bytes, 16 * 64);
    t.check_invariants();
}

#[test]
fn parent_never_evicted_before_child() {
    // Chain 1->2->3 fills GPU exactly; inserting 9 must evict the deepest
    // leaf (3), never the parent 1.
    let mut t = tree(48, 1000);
    let path = insert_path(&mut t, &[1, 2, 3], 16, 0.0);
    insert_path(&mut t, &[9], 16, 1.0);
    assert_eq!(t.node_tier(path[0]), Some(Tier::Gpu));
    assert_eq!(t.node_tier(path[1]), Some(Tier::Gpu));
    assert_eq!(t.node_tier(path[2]), Some(Tier::Host));
    t.check_invariants();
}

#[test]
fn swap_out_only_once_is_zero_copy_after_first() {
    let mut t = tree(16, 1000);
    let ids = insert_path(&mut t, &[1], 16, 0.0);
    // Evict 1 (first time: copies to host).
    insert_path(&mut t, &[2], 16, 1.0);
    assert_eq!(t.counters().swap_out_bytes, 16 * 64);
    assert_eq!(t.node_tier(ids[0]), Some(Tier::Host));
    // Promote 1 back to GPU (evicts 2), then evict 1 again (zero copy).
    let promo = t.promote(&ids);
    assert!(promo.complete(ids.len()), "promote succeeds");
    assert_eq!(promo.transfers.h2g_bytes, 16 * 64);
    assert_eq!(t.node_tier(ids[0]), Some(Tier::Gpu));
    insert_path(&mut t, &[3], 16, 2.0);
    // 1 went back to host without a second copy.
    assert_eq!(t.counters().swap_out_bytes, 2 * 16 * 64); // 1 once + 2 once
    assert!(t.counters().zero_copy_evictions >= 1);
    t.check_invariants();
}

#[test]
fn pinned_nodes_survive_pressure() {
    let mut t = tree(32, 64);
    let ids = insert_path(&mut t, &[1], 16, 0.0);
    t.pin(&ids);
    insert_path(&mut t, &[2], 16, 1.0);
    // Inserting a third 16-token doc requires evicting; only 2 is
    // evictable.
    insert_path(&mut t, &[3], 16, 2.0);
    assert_eq!(t.node_tier(ids[0]), Some(Tier::Gpu), "pinned stayed");
    t.unpin(&ids);
    t.check_invariants();
}

#[test]
fn everything_pinned_fails_cleanly() {
    let mut t = tree(16, 64);
    let ids = insert_path(&mut t, &[1], 16, 0.0);
    t.pin(&ids);
    assert!(t.insert_child(t.root(), 2, 16, None).1.is_none());
    t.unpin(&ids);
    assert!(t.insert_child(t.root(), 2, 16, None).1.is_some());
    t.check_invariants();
}

#[test]
fn host_overflow_drops_lowest_priority() {
    // Host fits 1 doc; two successive GPU evictions force a host
    // eviction.
    let mut t = tree(16, 16);
    insert_path(&mut t, &[1], 16, 0.0);
    insert_path(&mut t, &[2], 16, 1.0); // 1 -> host
    insert_path(&mut t, &[3], 16, 2.0); // 2 -> host, 1 dropped
    assert_eq!(t.counters().host_evictions, 1);
    assert_eq!(t.lookup(&[1]).matched_docs, 0, "doc 1 fully evicted");
    assert_eq!(t.lookup(&[2]).matched_docs, 1);
    t.check_invariants();
}

#[test]
fn oversized_doc_rejected_without_corruption() {
    let mut t = tree(32, 32);
    assert!(t.insert_child(t.root(), 1, 1000, None).1.is_none());
    assert_eq!(t.counters().rejected_inserts, 1);
    t.check_invariants();
}

#[test]
fn pgdsf_keeps_frequent_node() {
    let mut t = tree(32, 1000);
    let hot = insert_path(&mut t, &[1], 16, 0.0);
    let cold = insert_path(&mut t, &[2], 16, 0.5);
    // Touch doc 1 many times.
    for i in 0..10 {
        t.on_access(hot[0], &access(16, 1.0 + i as f64));
    }
    insert_path(&mut t, &[3], 16, 20.0);
    assert_eq!(t.node_tier(hot[0]), Some(Tier::Gpu), "hot stays");
    assert_eq!(t.node_tier(cold[0]), Some(Tier::Host), "cold evicted");
    t.check_invariants();
}

#[test]
fn clock_monotone_and_lifts_new_insertions() {
    let mut t = tree(16, 1000);
    insert_path(&mut t, &[1], 16, 0.0);
    let (c0, _) = t.clocks();
    insert_path(&mut t, &[2], 16, 1.0);
    let (c1, _) = t.clocks();
    insert_path(&mut t, &[3], 16, 2.0);
    let (c2, _) = t.clocks();
    assert!(c0 <= c1 && c1 <= c2);
    assert!(c2 > 0.0, "clock advanced after evictions");
}

#[test]
fn skeleton_recache_after_full_eviction() {
    let mut t = tree(16, 16);
    insert_path(&mut t, &[1], 16, 0.0);
    insert_path(&mut t, &[2], 16, 1.0); // 1 -> host
    insert_path(&mut t, &[3], 16, 2.0); // 1 dropped, 2 -> host
    assert_eq!(t.lookup(&[1]).matched_docs, 0);
    // Re-inserting doc 1 reuses the skeleton node.
    let n_before = t.node_count();
    insert_path(&mut t, &[1], 16, 3.0);
    assert_eq!(t.node_count(), n_before, "skeleton reused");
    assert_eq!(t.lookup(&[1]).matched_docs, 1);
    t.check_invariants();
}

/// Regression (transfer accounting): a promote that fails mid-path must
/// still report the h2g/g2h bytes of the prefix it DID move — the old
/// API returned `None` and dropped them, undercounting simulated PCIe
/// time and swap-out accounting.
#[test]
fn partial_promote_reports_prefix_transfers() {
    let mut t = tree(48, 1000); // GPU: 3 × 16-token slots
    let chain = insert_path(&mut t, &[1, 2], 16, 0.0); // a → b in GPU
    let f1 = insert_path(&mut t, &[10], 16, 0.1)[0]; // GPU full
    // Heat the fillers so the chain is always the eviction victim.
    for i in 0..10 {
        t.on_access(f1, &access(16, 1.0 + i as f64));
    }
    insert_path(&mut t, &[11], 16, 2.0); // evicts b -> host
    let f2 = t.lookup(&[11]).path[0];
    for i in 0..10 {
        t.on_access(f2, &access(16, 3.0 + i as f64));
    }
    insert_path(&mut t, &[12], 16, 20.0); // evicts a -> host
    let f3 = t.lookup(&[12]).path[0];
    assert_eq!(t.node_tier(chain[0]), Some(Tier::Host));
    assert_eq!(t.node_tier(chain[1]), Some(Tier::Host));

    // Pin two of the three GPU slots: promoting `a` can make room (by
    // evicting f3), promoting `b` cannot.
    t.pin(&[f1, f2]);
    let promo = t.promote(&chain);
    assert_eq!(promo.promoted, 1, "only the path prefix fit");
    assert_eq!(
        promo.transfers.h2g_bytes,
        16 * 64,
        "the promoted prefix's cache-hit load is charged"
    );
    assert_eq!(
        promo.transfers.g2h_bytes,
        16 * 64,
        "the eviction that made room for it is charged"
    );
    assert_eq!(t.node_tier(chain[0]), Some(Tier::Gpu));
    assert_eq!(t.node_tier(chain[1]), Some(Tier::Host));
    assert_eq!(t.node_tier(f3), Some(Tier::Host));
    t.unpin(&[f1, f2]);
    t.check_invariants();
}

/// Regression (skeleton re-cache): a failed re-insert of a fully evicted
/// node must leave the skeleton untouched. The old code mutated
/// `tokens` before securing GPU space, so an insert that never happened
/// left its token count behind.
#[test]
fn failed_skeleton_recache_leaves_tokens_untouched() {
    let mut t = tree(16, 16);
    insert_path(&mut t, &[1], 16, 0.0);
    let skel = t.lookup(&[1]).path[0];
    insert_path(&mut t, &[2], 16, 1.0); // 1 -> host
    insert_path(&mut t, &[3], 16, 2.0); // 2 -> host, 1 dropped to skeleton
    assert_eq!(t.node_tier(skel), None, "doc 1 is a skeleton");
    assert_eq!(t.node_tokens(skel), 16);

    // Pin the sole GPU resident so no space can be made, then try to
    // re-cache the skeleton with a DIFFERENT token count.
    let gpu_node = t.lookup(&[3]).path[0];
    t.pin(&[gpu_node]);
    let rejected_before = t.counters().rejected_inserts;
    assert!(t.insert_child(t.root(), 1, 8, None).1.is_none());
    assert_eq!(
        t.node_tokens(skel),
        16,
        "failed insert must not leave its token count behind"
    );
    assert_eq!(t.counters().rejected_inserts, rejected_before + 1);
    t.check_invariants();

    // Once space exists the re-cache succeeds and the new count wins.
    t.unpin(&[gpu_node]);
    let (_, id) = t.insert_child(t.root(), 1, 8, None);
    assert_eq!(id, Some(skel), "skeleton reused");
    assert_eq!(t.node_tokens(skel), 8);
    t.check_invariants();
}

/// Tentpole (dynamic tier budgets): growing is free, shrinking evicts
/// to fit through the normal replacement policy and reports its
/// swap-out transfers, and the accounting invariants hold throughout.
#[test]
fn resize_budgets_grow_and_shrink_with_eviction() {
    let p = page();
    let mut t = tree(48, 1000); // GPU: 3 × 16-token slots
    insert_path(&mut t, &[1], 16, 0.0);
    insert_path(&mut t, &[2], 16, 1.0);
    insert_path(&mut t, &[3], 16, 2.0);
    assert_eq!(t.gpu_used(), p.bytes(48));

    // Grow: no movement, capacity up.
    let moved = t.resize_budgets(p.bytes(64), p.bytes(1000)).unwrap();
    assert_eq!(moved, Transfers::default());
    assert_eq!(t.gpu_capacity(), p.bytes(64));

    // Shrink to one slot: two leaf evictions swap to host and are
    // reported as g2h transfers.
    let moved = t.resize_budgets(p.bytes(16), p.bytes(1000)).unwrap();
    assert_eq!(moved.g2h_bytes, 2 * 16 * 64);
    assert_eq!(t.gpu_capacity(), p.bytes(16));
    assert!(t.gpu_used() <= t.gpu_capacity());
    let occ = t.occupancy();
    assert_eq!(occ.gpu_capacity, p.bytes(16));
    assert_eq!(occ.gpu_used, t.gpu_used());
    t.check_invariants();
}

/// A shrink below what the pinned residents occupy is refused with NO
/// capacity change on either tier.
#[test]
fn resize_budgets_refused_when_pinned() {
    let p = page();
    let mut t = tree(32, 64);
    let a = insert_path(&mut t, &[1], 16, 0.0);
    let b = insert_path(&mut t, &[2], 16, 1.0);
    t.pin(&a);
    t.pin(&b);
    assert_eq!(
        t.resize_budgets(p.bytes(16), p.bytes(64)),
        Err(Transfers::default()),
        "both residents pinned: refused before anything moved"
    );
    assert_eq!(t.gpu_capacity(), p.bytes(32), "capacity untouched");
    assert_eq!(t.host_capacity(), p.bytes(64));
    t.unpin(&a);
    // With one unpinned leaf the same shrink now fits.
    let moved = t.resize_budgets(p.bytes(16), p.bytes(64)).unwrap();
    assert_eq!(moved.g2h_bytes, 16 * 64);
    assert_eq!(t.node_tier(b[0]), Some(Tier::Gpu), "pinned survived");
    assert_eq!(t.node_tier(a[0]), Some(Tier::Host));

    // A shrink below the pinned bytes is refused by the feasibility
    // pre-check BEFORE evicting anything — a doomed shrink must not
    // swap out the unpinned working set for nothing (a rebalancer
    // retrying each interval would repeat that damage).
    let evictions_before = t.counters().gpu_evictions;
    assert_eq!(
        t.resize_budgets(p.bytes(16) / 2, p.bytes(64)),
        Err(Transfers::default()),
        "target below pinned residents: infeasible"
    );
    assert_eq!(
        t.counters().gpu_evictions,
        evictions_before,
        "doomed shrink evicted nothing"
    );
    assert_eq!(t.gpu_capacity(), p.bytes(16), "capacity untouched");
    t.unpin(&b);
    t.check_invariants();
}

/// Host-tier shrinks drop host residents through the host frontier;
/// hit-bytes counting feeds the rebalancer's demand signal.
#[test]
fn resize_host_and_hit_bytes_counter() {
    let p = page();
    let mut t = tree(16, 32);
    insert_path(&mut t, &[1], 16, 0.0);
    insert_path(&mut t, &[2], 16, 1.0); // 1 -> host
    assert_eq!(t.host_used(), p.bytes(16));
    let moved = t.resize_budgets(p.bytes(16), 0).unwrap();
    assert_eq!(moved, Transfers::default(), "host drops move no bytes");
    assert_eq!(t.host_capacity(), 0);
    assert_eq!(t.host_used(), 0);
    assert_eq!(t.counters().host_evictions, 1);

    let m = t.lookup(&[2]);
    t.record_gpu_hit_bytes(&m.path);
    assert_eq!(t.counters().gpu_hit_bytes, 16 * 64);
    t.check_invariants();
}

#[test]
fn property_invariants_under_random_workload() {
    check_with(
        PropConfig { cases: 60, seed: 0xBEEF },
        "tree_invariants_random",
        |rng: &mut Rng| {
            let gpu_tokens = 32 + rng.index(8) * 16;
            let host_tokens = 32 + rng.index(16) * 16;
            let mut t = tree(gpu_tokens, host_tokens);
            let n_docs = 2 + rng.index(12) as u32;
            let mut now = 0.0;
            for _ in 0..60 {
                now += 0.1;
                let len = 1 + rng.index(3);
                let docs: Vec<DocId> =
                    (0..len).map(|_| rng.below(n_docs as u64) as u32).collect();
                let tokens = (1 + rng.index(3)) * 8;
                let m = t.lookup(&docs);
                t.pin(&m.path);
                if !t.promote(&m.path).complete(m.path.len()) {
                    t.unpin(&m.path);
                    continue;
                }
                // Insert the unmatched tail.
                let mut parent =
                    m.path.last().copied().unwrap_or(t.root());
                let mut inserted = m.path.clone();
                for &d in &docs[m.matched_docs..] {
                    match t.insert_child(parent, d, tokens, None) {
                        (_, Some(id)) => {
                            t.pin(&[id]);
                            inserted.push(id);
                            parent = id;
                        }
                        (_, None) => break,
                    }
                }
                for &id in &inserted {
                    t.on_access(id, &access(tokens, now));
                }
                t.unpin(&inserted);
                t.check_invariants();
            }
            // Final sanity: GPU usage within capacity.
            prop_assert!(t.gpu_used() <= t.gpu_used().max(1));
            Ok(())
        },
    );
}
