//! NVMe-backed third cache tier (`--disk on`): a slotted backing store
//! below the host tier, extending the eviction cascade to
//! GPU → host → disk → drop.
//!
//! At production corpus scale the host tier thrashes exactly the way
//! the GPU tier did before cross-shard rebalancing: evicted knowledge
//! KV is recomputed from scratch. The disk tier catches those
//! evictions instead — a host eviction *demotes* the entry's KV here
//! when the disk budget has room, and a later request *restages* it
//! disk → host → GPU instead of re-prefilling the document.
//!
//! Layout and charging model:
//!
//! - **Slotted backing store.** Payload rows are serialized into
//!   fixed-size slots (one KV page per slot, mirroring the vLLM block
//!   granularity of the RAM tiers), allocated from a free list — the
//!   in-memory moral equivalent of a page-aligned NVMe file. Byte
//!   accounting runs through the same [`TierAllocator`] type as the
//!   GPU/host tiers, so the rebalancer/occupancy machinery reads all
//!   three tiers uniformly.
//! - **Async staging queue.** Demotions enqueue; the budget is charged
//!   immediately but serialization into slots happens on a staging
//!   flush (a background thread in the real path, a per-iteration
//!   drain in the simulator). Spill *writes* therefore cost no request
//!   latency — only the `h2d` byte counters record them. Restage
//!   *reads* are synchronous: their `d2h` bytes coalesce into the
//!   per-batch staged-read burst charged beside the H2D burst (see
//!   [`crate::controller::BatchAdmission`]).
//! - **Pinned corpus entries** (CAG mode, "Don't Do RAG"): a pinned
//!   entry is restaged by *copy* — the disk copy is never freed, so a
//!   CAG tenant's corpus KV can always be recovered without recompute
//!   (the disk-tier analogue of swap-out-only-once).
//!
//! Keys are stable identities: a tree node's arena index (nodes are
//! never removed from the arena, only their tier/payload cleared) or a
//! chunk-cache document id for demoted owned entries.

use super::{DocId, NodeId};
use crate::kvcache::{KvPayload, TierAllocator};
use std::collections::{BTreeMap, VecDeque};

/// Identity of a disk-resident KV span: the tree node it belonged to,
/// or the chunk-cache document of a demoted owned entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub(crate) enum DiskKey {
    Node(NodeId),
    Chunk(DocId),
}

/// Outcome of a demotion attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum SpillOutcome {
    /// Entry accepted: budget charged, payload queued for staging.
    Stored,
    /// A pinned copy with the same span is already on disk — zero
    /// movement needed (the swap-out-only-once analogue).
    AlreadyPresent,
    /// The disk budget cannot hold it; the caller falls back to the
    /// pre-disk drop path.
    NoRoom,
}

/// What a restage recovered.
#[derive(Debug)]
pub(crate) struct Restaged {
    pub tokens: usize,
    /// RoPE base offset recorded at demotion (chunk entries).
    pub rope_offset: usize,
    /// Page-rounded bytes the entry held on disk.
    pub bytes: u64,
    /// The recovered KV rows (None in accounting-only simulation).
    pub payload: Option<KvPayload>,
    /// Whether the disk copy was retained (pinned corpus entries).
    pub retained: bool,
}

/// Fixed-size slot store: the file layout. Each slot holds one KV page
/// worth of serialized rows; freed slots are reused LIFO.
#[derive(Debug)]
struct SlottedStore {
    slot_bytes: usize,
    slots: Vec<Option<Vec<u8>>>,
    free: Vec<usize>,
}

impl SlottedStore {
    fn new(slot_bytes: usize) -> Self {
        SlottedStore {
            slot_bytes: slot_bytes.max(1),
            slots: Vec::new(),
            free: Vec::new(),
        }
    }

    /// Write `data` across as many slots as it needs, returning them.
    fn write(&mut self, data: &[u8]) -> Vec<usize> {
        let mut out = Vec::new();
        for chunk in data.chunks(self.slot_bytes) {
            let idx = match self.free.pop() {
                Some(i) => {
                    self.slots[i] = Some(chunk.to_vec());
                    i
                }
                None => {
                    self.slots.push(Some(chunk.to_vec()));
                    self.slots.len() - 1
                }
            };
            out.push(idx);
        }
        out
    }

    /// Reassemble `byte_len` bytes from `slots` in order.
    fn read(&self, slots: &[usize], byte_len: usize) -> Vec<u8> {
        let mut out = Vec::with_capacity(byte_len);
        for &i in slots {
            let data = self.slots[i]
                .as_ref()
                .expect("reading a freed disk slot");
            out.extend_from_slice(data);
        }
        debug_assert_eq!(out.len(), byte_len);
        out
    }

    fn release(&mut self, slots: &[usize]) {
        for &i in slots {
            debug_assert!(self.slots[i].is_some(), "double-free of slot");
            self.slots[i] = None;
            self.free.push(i);
        }
    }

    fn live_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }
}

/// Where an entry's payload currently lives.
#[derive(Debug)]
enum EntryState {
    /// Queued for the staging writer; rows still in memory.
    Staged(Option<KvPayload>),
    /// Serialized into backing-store slots.
    Stored {
        slots: Vec<usize>,
        byte_len: usize,
        has_payload: bool,
    },
}

/// One disk-resident KV span.
#[derive(Debug)]
struct DiskEntry {
    tokens: usize,
    rope_offset: usize,
    /// Page-rounded bytes charged against the disk allocator.
    bytes: u64,
    /// CAG corpus pin: restage copies, the disk copy is never freed.
    pinned: bool,
    state: EntryState,
}

/// The disk tier: budget accounting + slotted store + staging queue.
#[derive(Debug)]
pub(crate) struct DiskTier {
    alloc: TierAllocator,
    store: SlottedStore,
    entries: BTreeMap<DiskKey, DiskEntry>,
    /// Keys awaiting the staging writer, in demotion order.
    staging: VecDeque<DiskKey>,
}

fn serialize(p: &KvPayload) -> Vec<u8> {
    let mut out = Vec::with_capacity(p.floats().len() * 4);
    for f in p.floats() {
        out.extend_from_slice(&f.to_le_bytes());
    }
    out
}

fn deserialize(bytes: &[u8], tokens: usize) -> KvPayload {
    let floats: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    KvPayload::new(floats, tokens)
}

impl DiskTier {
    pub fn new(capacity: u64, slot_bytes: usize) -> Self {
        DiskTier {
            alloc: TierAllocator::new(capacity),
            store: SlottedStore::new(slot_bytes),
            entries: BTreeMap::new(),
            staging: VecDeque::new(),
        }
    }

    pub fn used(&self) -> u64 {
        self.alloc.used()
    }

    pub fn capacity(&self) -> u64 {
        self.alloc.capacity()
    }

    pub fn contains(&self, key: DiskKey) -> bool {
        self.entries.contains_key(&key)
    }

    /// Recorded token span of a disk entry (restage validation).
    pub fn entry_tokens(&self, key: DiskKey) -> Option<usize> {
        self.entries.get(&key).map(|e| e.tokens)
    }

    pub fn entry_count(&self) -> usize {
        self.entries.len()
    }

    /// Entries still queued for the staging writer.
    pub fn staged_len(&self) -> usize {
        self.staging.len()
    }

    /// All resident keys in order — the tree's invariant checker walks
    /// these to cross-validate node-keyed entries against the arena.
    pub fn keys(&self) -> impl Iterator<Item = DiskKey> + '_ {
        self.entries.keys().copied()
    }

    /// Demote a KV span to disk. `bytes` is the page-rounded charge.
    /// The budget is charged immediately; the payload rides the async
    /// staging queue until the next flush. A same-span entry already on
    /// disk (a pinned corpus copy surviving its restage) reports
    /// [`SpillOutcome::AlreadyPresent`] — zero movement; a stale entry
    /// with a different span is replaced (its pin carries over).
    pub fn spill(
        &mut self,
        key: DiskKey,
        tokens: usize,
        rope_offset: usize,
        bytes: u64,
        payload: Option<KvPayload>,
        pinned: bool,
    ) -> SpillOutcome {
        let mut keep_pin = pinned;
        if let Some(e) = self.entries.get(&key) {
            if e.tokens == tokens {
                return SpillOutcome::AlreadyPresent;
            }
            keep_pin |= e.pinned;
            self.discard(key);
        }
        if !self.alloc.alloc(bytes) {
            return SpillOutcome::NoRoom;
        }
        self.entries.insert(
            key,
            DiskEntry {
                tokens,
                rope_offset,
                bytes,
                pinned: keep_pin,
                state: EntryState::Staged(payload),
            },
        );
        self.staging.push_back(key);
        SpillOutcome::Stored
    }

    /// Bring an entry back from disk. Unpinned entries are consumed
    /// (slots freed, budget released); pinned corpus entries are read
    /// by copy and retained. Returns None when the key is absent.
    pub fn restage(&mut self, key: DiskKey) -> Option<Restaged> {
        let pinned = self.entries.get(&key)?.pinned;
        if pinned {
            let e = self.entries.get(&key)?;
            let payload = match &e.state {
                EntryState::Staged(p) => p.clone(),
                EntryState::Stored {
                    slots,
                    byte_len,
                    has_payload,
                } => has_payload.then(|| {
                    deserialize(
                        &self.store.read(slots, *byte_len),
                        e.tokens,
                    )
                }),
            };
            return Some(Restaged {
                tokens: e.tokens,
                rope_offset: e.rope_offset,
                bytes: e.bytes,
                payload,
                retained: true,
            });
        }
        let e = self.entries.remove(&key)?;
        let payload = match e.state {
            EntryState::Staged(p) => p,
            EntryState::Stored {
                slots,
                byte_len,
                has_payload,
            } => {
                let p = has_payload.then(|| {
                    deserialize(
                        &self.store.read(&slots, byte_len),
                        e.tokens,
                    )
                });
                self.store.release(&slots);
                p
            }
        };
        self.alloc.release(e.bytes);
        Some(Restaged {
            tokens: e.tokens,
            rope_offset: e.rope_offset,
            bytes: e.bytes,
            payload,
            retained: false,
        })
    }

    /// Drop an entry without reading it (a stale span superseded by a
    /// re-cached node). Returns whether anything was dropped.
    pub fn discard(&mut self, key: DiskKey) -> bool {
        let Some(e) = self.entries.remove(&key) else {
            return false;
        };
        if let EntryState::Stored { slots, .. } = &e.state {
            self.store.release(slots);
        }
        self.alloc.release(e.bytes);
        true
    }

    /// Drain the async staging queue: serialize every still-queued
    /// payload into backing-store slots. Returns entries written. The
    /// real path runs this on a background staging thread; the
    /// simulator drains once per engine iteration.
    pub fn flush_staging(&mut self) -> usize {
        let mut written = 0;
        while let Some(key) = self.staging.pop_front() {
            let Some(e) = self.entries.get_mut(&key) else {
                continue; // restaged or discarded before the flush
            };
            let EntryState::Staged(payload) = &e.state else {
                continue; // already flushed (re-queued pin)
            };
            let (slots, byte_len, has_payload) = match payload {
                Some(p) => {
                    let data = serialize(p);
                    let len = data.len();
                    (self.store.write(&data), len, true)
                }
                None => (Vec::new(), 0, false),
            };
            e.state = EntryState::Stored {
                slots,
                byte_len,
                has_payload,
            };
            written += 1;
        }
        written
    }

    /// Structural invariants: budget accounting matches the entry set,
    /// and every backing-store slot is owned by exactly one entry.
    pub fn check_invariants(&self) {
        let total: u64 = self.entries.values().map(|e| e.bytes).sum();
        assert_eq!(total, self.alloc.used(), "disk accounting");
        let mut seen = std::collections::BTreeSet::new();
        for (key, e) in &self.entries {
            if let EntryState::Stored {
                slots, byte_len, ..
            } = &e.state
            {
                for &s in slots {
                    assert!(
                        seen.insert(s),
                        "slot {s} owned twice ({key:?})"
                    );
                    assert!(
                        self.store.slots[s].is_some(),
                        "live slot {s} freed ({key:?})"
                    );
                }
                let cap = slots.len() * self.store.slot_bytes;
                assert!(
                    *byte_len <= cap,
                    "entry {key:?}: {byte_len} B in {cap} B of slots"
                );
            }
        }
        assert_eq!(
            seen.len(),
            self.store.live_slots(),
            "orphaned live slots in the backing store"
        );
        for key in &self.staging {
            // A queued key may have been consumed already (restage
            // before flush); if present it must still be staged.
            if let Some(e) = self.entries.get(key) {
                assert!(
                    matches!(e.state, EntryState::Staged(_)),
                    "queued entry {key:?} already stored"
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn node_key(i: usize) -> DiskKey {
        DiskKey::Node(NodeId(i))
    }

    fn payload(tokens: usize, seed: f32) -> KvPayload {
        let data: Vec<f32> =
            (0..tokens * 4).map(|i| seed + i as f32).collect();
        KvPayload::new(data, tokens)
    }

    #[test]
    fn spill_restage_roundtrips_payload_bits() {
        let mut d = DiskTier::new(4096, 128);
        let p = payload(16, 0.5);
        assert_eq!(
            d.spill(node_key(1), 16, 0, 1024, Some(p.clone()), false),
            SpillOutcome::Stored
        );
        assert_eq!(d.used(), 1024);
        // Through the staging queue AND the slotted store.
        assert_eq!(d.flush_staging(), 1);
        d.check_invariants();
        let r = d.restage(node_key(1)).expect("present");
        assert_eq!(r.tokens, 16);
        assert!(!r.retained);
        assert_eq!(r.payload.unwrap().floats(), p.floats());
        assert_eq!(d.used(), 0, "unpinned restage frees the bytes");
        d.check_invariants();
    }

    #[test]
    fn restage_before_flush_serves_from_queue() {
        let mut d = DiskTier::new(4096, 128);
        let p = payload(8, 3.0);
        d.spill(node_key(2), 8, 0, 512, Some(p.clone()), false);
        let r = d.restage(node_key(2)).expect("staged entry readable");
        assert_eq!(r.payload.unwrap().floats(), p.floats());
        // The queued key is now dangling; flush skips it cleanly.
        assert_eq!(d.flush_staging(), 0);
        assert_eq!(d.used(), 0);
        d.check_invariants();
    }

    #[test]
    fn pinned_entry_is_restaged_by_copy() {
        let mut d = DiskTier::new(4096, 64);
        let p = payload(8, 7.0);
        d.spill(DiskKey::Chunk(9), 8, 4, 512, Some(p.clone()), true);
        d.flush_staging();
        for _ in 0..2 {
            let r = d.restage(DiskKey::Chunk(9)).expect("retained");
            assert!(r.retained);
            assert_eq!(r.rope_offset, 4);
            assert_eq!(r.payload.unwrap().floats(), p.floats());
        }
        assert_eq!(d.used(), 512, "pinned copy never freed");
        // Re-demoting the same span is free (already present).
        assert_eq!(
            d.spill(DiskKey::Chunk(9), 8, 4, 512, Some(p), true),
            SpillOutcome::AlreadyPresent
        );
        assert_eq!(d.used(), 512);
        d.check_invariants();
    }

    #[test]
    fn budget_refusal_and_slot_reuse() {
        let mut d = DiskTier::new(1024, 32);
        assert_eq!(
            d.spill(node_key(1), 16, 0, 1024, Some(payload(16, 0.0)), false),
            SpillOutcome::Stored
        );
        assert_eq!(
            d.spill(node_key(2), 4, 0, 256, None, false),
            SpillOutcome::NoRoom
        );
        d.flush_staging();
        let slots_before = d.store.slots.len();
        d.restage(node_key(1));
        // Freed slots are reused, not leaked.
        d.spill(node_key(3), 16, 0, 1024, Some(payload(16, 1.0)), false);
        d.flush_staging();
        assert_eq!(d.store.slots.len(), slots_before);
        d.check_invariants();
    }

    #[test]
    fn stale_span_is_replaced_and_accounting_only_entries_work() {
        let mut d = DiskTier::new(4096, 128);
        d.spill(node_key(5), 8, 0, 512, None, false);
        d.flush_staging();
        // Same key, new span (skeleton re-cached with new tokens).
        assert_eq!(
            d.spill(node_key(5), 16, 0, 1024, None, false),
            SpillOutcome::Stored
        );
        assert_eq!(d.used(), 1024, "old charge released");
        let r = d.restage(node_key(5)).expect("present");
        assert_eq!(r.tokens, 16);
        assert!(r.payload.is_none(), "accounting-only entry");
        d.check_invariants();
    }
}
