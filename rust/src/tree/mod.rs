//! The knowledge tree (paper §5.1, Fig. 8): a prefix tree over document
//! IDs whose nodes hold the KV tensors of one document *in the context of
//! its ancestors* — the order-sensitivity of attention means `[D1,D3]`
//! and `[D2,D3]` produce different KV for `D3`, hence a tree, not a map.
//!
//! Nodes are partitioned across the memory hierarchy: a GPU segment (a
//! connected top region including the root), a host segment below it,
//! an optional disk segment ([`disk_tier`], `--disk on`), and free
//! (uncached). Eviction is leaf-frontier-only (Algorithm 1
//! `EVICT_IN_GPU`), preserving the invariant that every cached node's
//! parent is cached in the same or faster tier. Swap-out-only-once
//! (§5.1) keeps a host copy after the first GPU eviction so later GPU
//! evictions are zero-copy.
//!
//! With the disk tier enabled the eviction cascade is
//! GPU → host → disk → drop: a host eviction (or a GPU eviction the
//! host cannot absorb) *demotes* the KV to the slotted disk store when
//! the disk budget has room, and the prefix walk *restages* a
//! disk-resident node (disk → host, then the normal host → GPU
//! promotion) instead of treating it as a miss:
//!
//! ```text
//!                    ┌────────────── eviction cascade ──────────────┐
//!   GPU tier ──g2h──► host tier ──h2d──► disk tier ──(no room)──► drop
//!      ▲   promote      ▲    restage       │
//!      └──h2g───────────┴────d2h───────────┘   (admission path)
//! ```
//!
//! Beside the tree sits the optional **chunk cache** ([`chunk_cache`],
//! `--chunk-cache on`): a per-document registry enabling
//! position-independent KV reuse with boundary-token recompute. Lookup
//! order is prefix walk → chunk probe → (disk restage → re-probe) →
//! miss:
//!
//! ```text
//!   request docs ──► prefix walk (tree) ──► matched prefix → α
//!                        │ docs that miss the prefix path      ▲
//!                        ▼                               disk restage
//!                    chunk probe ──► hit: reuse at ANY position
//!                        │           (tokens − r into α, r boundary
//!                        │            tokens into β; h2g bytes ride
//!                        ▼            the per-batch H2D burst)
//!                      miss ──► full prefill (β), insert into tree
//!
//!   tier bytes:  tree nodes and OWNED chunk entries share the same
//!   GPU/host TierAllocators and compete for eviction under the same
//!   policy + per-tier clocks; a doc cached as a tree node is only a
//!   zero-byte Ref in the chunk registry (no double residency). The
//!   disk tier holds demoted nodes (keyed by arena index) and demoted
//!   owned chunk entries (keyed by doc), plus CAG-pinned corpus
//!   entries that are restaged by copy (never freed).
//! ```

pub mod chunk_cache;
pub mod disk_tier;

use crate::kvcache::{KvPayload, PageSpec, Tier, TierAllocator};
use crate::policy::{AccessCtx, NodeStats, ReplacementPolicy};
use chunk_cache::{ChunkEntry, ChunkSlot, ChunkState};
pub use chunk_cache::{ChunkHit, ChunkSource};
use disk_tier::{DiskKey, DiskTier, SpillOutcome};
use std::collections::BTreeMap;

/// Document identifier (knowledge-base key).
pub type DocId = u32;

/// Node handle (index into the tree's arena).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub usize);

#[derive(Debug)]
struct Node {
    doc: DocId,
    parent: Option<NodeId>,
    children: BTreeMap<DocId, NodeId>,
    tokens: usize,
    /// Where the KV currently lives; None = uncached.
    tier: Option<Tier>,
    /// Swap-out-only-once: a host copy exists (kept even while
    /// GPU-resident, until evicted from the whole cache).
    host_copy: bool,
    /// In-flight requests referencing this node; pinned nodes are never
    /// evicted.
    pinned: u32,
    stats: NodeStats,
    payload: Option<KvPayload>,
}

/// Result of a prefix match (paper: "prefix matching along these paths").
#[derive(Debug, Clone, Default)]
pub struct MatchResult {
    /// Matched nodes in path order (root excluded).
    pub path: Vec<NodeId>,
    /// How many of the requested docs matched.
    pub matched_docs: usize,
    /// Total cached tokens along the match (the request's α).
    pub cached_tokens: usize,
    /// Of which resident in GPU / host.
    pub gpu_tokens: usize,
    pub host_tokens: usize,
}

/// Byte movement triggered by an operation — the controller turns these
/// into (simulated or real) PCIe time.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Transfers {
    /// Host→GPU bytes (cache-hit loading).
    pub h2g_bytes: u64,
    /// GPU→host bytes (first-time swap-outs).
    pub g2h_bytes: u64,
    /// Host→disk bytes (third-tier demotions, `--disk on`). Spills ride
    /// the async staging queue, so they are *counted* here but never
    /// charged as request latency.
    pub h2d_bytes: u64,
    /// Disk→host bytes (restage reads). Synchronous: they coalesce into
    /// the per-batch staged-read burst, charged beside the H2D burst
    /// through [`PipelineDriver::disk_read_time`]
    /// (`crate::controller::PipelineDriver`).
    pub d2h_bytes: u64,
}

impl Transfers {
    pub fn merge(&mut self, other: Transfers) {
        self.h2g_bytes += other.h2g_bytes;
        self.g2h_bytes += other.g2h_bytes;
        self.h2d_bytes += other.h2d_bytes;
        self.d2h_bytes += other.d2h_bytes;
    }
}

/// Outcome of [`KnowledgeTree::promote`]: how much of the path made it
/// into GPU, and the byte movement performed getting there — including
/// the bytes of a prefix promoted before a mid-path failure, so callers
/// always charge PCIe time for what actually moved.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Promotion {
    /// Bytes moved: cache-hit loading (h2g) plus eviction swap-outs
    /// (g2h), whether or not the whole path was promoted.
    pub transfers: Transfers,
    /// Length of the `path` prefix that is now GPU-resident.
    pub promoted: usize,
}

impl Promotion {
    /// Whether every node of the requested path was promoted.
    pub fn complete(&self, path_len: usize) -> bool {
        self.promoted == path_len
    }
}

/// Aggregate counters for observability and the ablation benches.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TreeCounters {
    pub gpu_evictions: u64,
    pub host_evictions: u64,
    pub swap_out_bytes: u64,
    pub zero_copy_evictions: u64,
    pub inserts: u64,
    pub rejected_inserts: u64,
    /// KV bytes served from the GPU-resident (promoted + pinned) prefix
    /// at admission time — the per-shard demand signal the cross-shard
    /// rebalancer feeds on, and the aggregate the skewed-workload CI
    /// gate compares.
    pub gpu_hit_bytes: u64,
    /// Position-independent chunk-cache hits (probe successes).
    pub chunk_hits: u64,
    /// KV bytes served from chunk entries (the reused `tokens − r`
    /// rows) — counted into the rebalancer's demand alongside
    /// `gpu_hit_bytes`.
    pub chunk_hit_bytes: u64,
    /// Boundary tokens re-prefilled across all chunk hits (the `r`-token
    /// cross-attention repair cost).
    pub boundary_recompute_tokens: u64,
    /// Host→disk demotions accepted by the third tier (`--disk on`).
    pub disk_spills: u64,
    /// Payload bytes those demotions wrote (async, uncharged).
    pub disk_spill_bytes: u64,
    /// Disk-resident entries restaged on the admission path instead of
    /// recomputed — the third tier's hit counter.
    pub disk_restage_hits: u64,
    /// Payload bytes those restages read (charged per-batch as one
    /// staged-read burst).
    pub disk_restage_bytes: u64,
}

impl TreeCounters {
    /// Field-wise sum — aggregates per-shard counters for the `Stats`
    /// endpoint and metrics. Driven by the registry's field table
    /// ([`crate::metrics::registry::TREE_COUNTER_FIELDS`]), so a new
    /// counter declared there is summed with no edit here; the table's
    /// exhaustiveness is pinned by the registry conformance tests.
    pub fn merge(&mut self, other: TreeCounters) {
        for f in crate::metrics::registry::TREE_COUNTER_FIELDS.iter() {
            let v = (f.get)(self) + (f.get)(&other);
            (f.set)(self, v);
        }
    }
}

/// Tier occupancy gauge of one tree (one shard): the used-vs-capacity
/// signal the cross-shard rebalancer and the stats endpoint read.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TierOccupancy {
    pub gpu_used: u64,
    pub gpu_capacity: u64,
    pub host_used: u64,
    pub host_capacity: u64,
    /// Third-tier gauges; both zero with `--disk off`.
    pub disk_used: u64,
    pub disk_capacity: u64,
}

/// The multilevel knowledge tree.
pub struct KnowledgeTree {
    nodes: Vec<Node>,
    root: NodeId,
    gpu: TierAllocator,
    host: TierAllocator,
    page: PageSpec,
    policy: Box<dyn ReplacementPolicy>,
    /// Per-tier logical clocks (Eq. 2).
    clock_gpu: f64,
    clock_host: f64,
    swap_out_only_once: bool,
    counters: TreeCounters,
    /// Tier-membership indexes: victim selection scans only residents of
    /// the relevant tier instead of every node ever created (§Perf: this
    /// took eviction from O(total nodes) to O(resident nodes)).
    gpu_resident: std::collections::BTreeSet<usize>,
    host_resident: std::collections::BTreeSet<usize>,
    /// Chunk-cache registry (`--chunk-cache on`); None = disabled, and
    /// the tree behaves bit-identically to the chunk-free path.
    chunk: Option<ChunkState>,
    /// Disk third tier (`--disk on`); None = disabled, and every code
    /// path reduces structurally to the two-tier cascade.
    disk: Option<DiskTier>,
}

impl KnowledgeTree {
    /// Create a tree. `system_prompt_tokens` sizes the root node S, which
    /// is permanently pinned in GPU (paper Fig. 8).
    pub fn new(
        gpu_bytes: u64,
        host_bytes: u64,
        page: PageSpec,
        policy: Box<dyn ReplacementPolicy>,
        swap_out_only_once: bool,
        system_prompt_tokens: usize,
    ) -> Self {
        let mut gpu = TierAllocator::new(gpu_bytes);
        let root_bytes = page.bytes(system_prompt_tokens);
        assert!(
            gpu.alloc(root_bytes),
            "system prompt does not fit in GPU cache"
        );
        let root_node = Node {
            doc: DocId::MAX,
            parent: None,
            children: BTreeMap::new(),
            tokens: system_prompt_tokens,
            tier: Some(Tier::Gpu),
            host_copy: false,
            pinned: 1, // never evicted
            stats: NodeStats::default(),
            payload: None,
        };
        let mut gpu_resident = std::collections::BTreeSet::new();
        gpu_resident.insert(0);
        KnowledgeTree {
            nodes: vec![root_node],
            root: NodeId(0),
            gpu,
            host: TierAllocator::new(host_bytes),
            page,
            policy,
            clock_gpu: 0.0,
            clock_host: 0.0,
            swap_out_only_once,
            counters: TreeCounters::default(),
            gpu_resident,
            host_resident: std::collections::BTreeSet::new(),
            chunk: None,
            disk: None,
        }
    }

    /// Enable the NVMe-backed third tier with a `disk_bytes` budget.
    /// Called at build time; a tree never enabled carries no disk state
    /// at all — the off path is structurally the two-tier cascade.
    pub fn enable_disk_tier(&mut self, disk_bytes: u64) {
        let slot_bytes =
            self.page.block_tokens * self.page.kv_bytes_per_token;
        self.disk = Some(DiskTier::new(disk_bytes, slot_bytes));
    }

    pub fn disk_enabled(&self) -> bool {
        self.disk.is_some()
    }

    pub fn disk_used(&self) -> u64 {
        self.disk.as_ref().map_or(0, |d| d.used())
    }

    pub fn disk_capacity(&self) -> u64 {
        self.disk.as_ref().map_or(0, |d| d.capacity())
    }

    /// Disk-resident entries (nodes + demoted chunk entries).
    pub fn disk_entry_count(&self) -> usize {
        self.disk.as_ref().map_or(0, |d| d.entry_count())
    }

    /// Demotions still queued for the async staging writer.
    pub fn disk_staged_len(&self) -> usize {
        self.disk.as_ref().map_or(0, |d| d.staged_len())
    }

    /// Drain the async staging queue into the slotted store. The real
    /// path calls this from its background staging thread; the
    /// simulator drains once per engine iteration. Returns entries
    /// written; a no-op (0) with the disk tier off.
    pub fn flush_disk_staging(&mut self) -> usize {
        self.disk.as_mut().map_or(0, |d| d.flush_staging())
    }

    /// Enable chunk-level position-independent reuse with `r =
    /// boundary_tokens` re-prefilled per cross-position hit. Called at
    /// build time; a tree never enabled carries no chunk state at all.
    pub fn enable_chunk_cache(&mut self, boundary_tokens: usize) {
        self.chunk = Some(ChunkState::new(boundary_tokens));
    }

    pub fn chunk_cache_enabled(&self) -> bool {
        self.chunk.is_some()
    }

    /// Live chunk registry entries (owned + valid tree refs) — test
    /// and observability helper.
    pub fn chunk_entry_count(&self) -> usize {
        let Some(state) = self.chunk.as_ref() else {
            return 0;
        };
        state
            .slots
            .values()
            .filter(|slot| match slot {
                ChunkSlot::Ref(id) => self.nodes[id.0].tier.is_some(),
                ChunkSlot::Owned(e) => !e.doomed,
            })
            .count()
    }

    /// Set a node's tier, keeping the residency indexes consistent.
    fn set_tier(&mut self, id: NodeId, tier: Option<Tier>) {
        match self.nodes[id.0].tier {
            Some(Tier::Gpu) => {
                self.gpu_resident.remove(&id.0);
            }
            Some(Tier::Host) => {
                self.host_resident.remove(&id.0);
            }
            None => {}
        }
        match tier {
            Some(Tier::Gpu) => {
                self.gpu_resident.insert(id.0);
            }
            Some(Tier::Host) => {
                self.host_resident.insert(id.0);
            }
            None => {}
        }
        self.nodes[id.0].tier = tier;
    }

    pub fn root(&self) -> NodeId {
        self.root
    }

    pub fn counters(&self) -> TreeCounters {
        self.counters
    }

    pub fn gpu_used(&self) -> u64 {
        self.gpu.used()
    }

    pub fn host_used(&self) -> u64 {
        self.host.used()
    }

    pub fn gpu_capacity(&self) -> u64 {
        self.gpu.capacity()
    }

    pub fn host_capacity(&self) -> u64 {
        self.host.capacity()
    }

    /// Snapshot of all tiers' used/capacity gauges.
    pub fn occupancy(&self) -> TierOccupancy {
        TierOccupancy {
            gpu_used: self.gpu.used(),
            gpu_capacity: self.gpu.capacity(),
            host_used: self.host.used(),
            host_capacity: self.host.capacity(),
            disk_used: self.disk_used(),
            disk_capacity: self.disk_capacity(),
        }
    }

    /// Count the KV bytes an admission serves from its GPU-resident
    /// (promoted + pinned) prefix — the rebalancer's demand signal.
    pub fn record_gpu_hit_bytes(&mut self, path: &[NodeId]) {
        self.counters.gpu_hit_bytes += path
            .iter()
            .map(|&n| self.page.payload_bytes(self.nodes[n.0].tokens))
            .sum::<u64>();
    }

    /// Dynamically retarget the tier budgets (cross-shard rebalancing).
    /// Growth always applies; a shrink first evicts-to-fit through the
    /// normal replacement policy — GPU leaf-frontier order with
    /// swap-out-to-host, host leaf-frontier drops — with pinned nodes
    /// immovable, exactly as under admission pressure. `Ok` carries the
    /// swap-out transfers performed so the caller keeps PCIe time
    /// charged; `Err` means eviction could not make the residents fit
    /// (everything left is pinned) and NO capacity changed on either
    /// tier — but its payload still carries the transfers of the
    /// evictions performed before the refusal, which stay in effect
    /// and in the counters: like every other mid-path failure here,
    /// bytes that actually moved are never uncounted.
    pub fn resize_budgets(
        &mut self,
        gpu_bytes: u64,
        host_bytes: u64,
    ) -> Result<Transfers, Transfers> {
        let mut transfers = Transfers::default();
        // Feasibility first: if the pinned residents (plus their
        // ancestor chains, which leaf-frontier eviction can never get
        // past) already exceed the GPU target, refuse BEFORE evicting
        // anything — otherwise a doomed shrink would swap out the
        // whole unpinned working set for nothing, and a rebalancer
        // retrying each interval would repeat that damage forever.
        if gpu_bytes < self.gpu.used()
            && self.gpu_unevictable_bytes() > gpu_bytes
        {
            return Err(transfers);
        }
        // Evict-to-fit BEFORE touching either capacity, so a refusal
        // changes no budget. GPU first: its swap-outs land in host
        // (within the host tier's CURRENT capacity — a simultaneous
        // host grow applies only at the end, which is why the
        // rebalancer resizes one tier at a time), and the host pass
        // then trims against the new host target.
        while self.gpu.used() > gpu_bytes {
            if !self.evict_one_gpu(&mut transfers) {
                return Err(transfers);
            }
        }
        while self.host.used() > host_bytes {
            if !self.evict_one_host(None, &mut transfers) {
                return Err(transfers);
            }
        }
        let gpu_ok = self.gpu.set_capacity(gpu_bytes);
        let host_ok = self.host.set_capacity(host_bytes);
        debug_assert!(gpu_ok && host_ok, "evicted to fit above");
        Ok(transfers)
    }

    /// Lower bound of GPU bytes leaf-frontier eviction can never free:
    /// pinned GPU residents plus their ancestor chains (an ancestor
    /// cannot be evicted while a pinned descendant is GPU-resident —
    /// the hierarchy invariant keeps it below the frontier). This is
    /// exact: every node outside this set heads a pin-free subtree,
    /// peelable bottom-up.
    fn gpu_unevictable_bytes(&self) -> u64 {
        let mut keep = std::collections::BTreeSet::new();
        for &i in &self.gpu_resident {
            if self.nodes[i].pinned == 0 {
                continue;
            }
            let mut cur = Some(NodeId(i));
            while let Some(id) = cur {
                if !keep.insert(id.0) {
                    break; // shared ancestor chain already walked
                }
                cur = self.nodes[id.0].parent;
            }
        }
        let mut total: u64 = keep
            .iter()
            .map(|&i| self.page.bytes(self.nodes[i].tokens))
            .sum();
        // Pinned GPU-resident owned chunk entries are just as immovable.
        if let Some(state) = &self.chunk {
            for slot in state.slots.values() {
                if let ChunkSlot::Owned(e) = slot {
                    if e.tier == Tier::Gpu && e.pinned > 0 {
                        total += self.page.bytes(e.tokens);
                    }
                }
            }
        }
        total
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    pub fn node_tokens(&self, id: NodeId) -> usize {
        self.nodes[id.0].tokens
    }

    pub fn node_tier(&self, id: NodeId) -> Option<Tier> {
        self.nodes[id.0].tier
    }

    pub fn node_doc(&self, id: NodeId) -> DocId {
        self.nodes[id.0].doc
    }

    pub fn node_payload(&self, id: NodeId) -> Option<&KvPayload> {
        self.nodes[id.0].payload.as_ref()
    }

    pub fn node_stats(&self, id: NodeId) -> &NodeStats {
        &self.nodes[id.0].stats
    }

    /// O(h) prefix match of a document sequence against the tree
    /// (terminates at the first miss — paper §5.1).
    pub fn lookup(&self, docs: &[DocId]) -> MatchResult {
        let mut result = MatchResult::default();
        let mut cur = self.root;
        for &doc in docs {
            let Some(&child) = self.nodes[cur.0].children.get(&doc) else {
                break;
            };
            let node = &self.nodes[child.0];
            let Some(tier) = node.tier else {
                break; // uncached skeleton node: stop, it is a miss
            };
            result.path.push(child);
            result.matched_docs += 1;
            result.cached_tokens += node.tokens;
            match tier {
                Tier::Gpu => result.gpu_tokens += node.tokens,
                Tier::Host => result.host_tokens += node.tokens,
            }
            cur = child;
        }
        result
    }

    /// Prefix match that treats a disk-resident node as a hit: when the
    /// walk reaches an uncached skeleton node whose KV the disk tier
    /// holds, the node is restaged disk → host (charged as `d2h` bytes
    /// into `transfers`; the controller coalesces them into one
    /// staged-read burst per admitted batch) and the walk continues.
    /// Each matched node is pinned for the duration of the walk, so the
    /// host evictions a later restage may cascade can never evict an
    /// earlier match out from under the admission. With the disk tier
    /// off this is exactly [`KnowledgeTree::lookup`].
    pub fn lookup_restage(
        &mut self,
        docs: &[DocId],
        transfers: &mut Transfers,
    ) -> MatchResult {
        let mut result = MatchResult::default();
        let mut cur = self.root;
        for &doc in docs {
            let Some(&child) = self.nodes[cur.0].children.get(&doc) else {
                break;
            };
            if self.nodes[child.0].tier.is_none()
                && !self.restage_node(child, transfers)
            {
                break; // uncached and not on disk: a genuine miss
            }
            let node = &self.nodes[child.0];
            let tier = node.tier.expect("cached or restaged above");
            result.path.push(child);
            result.matched_docs += 1;
            result.cached_tokens += node.tokens;
            match tier {
                Tier::Gpu => result.gpu_tokens += node.tokens,
                Tier::Host => result.host_tokens += node.tokens,
            }
            // Walk-duration pin (released below): the hierarchy keeps a
            // restaged child's ancestors cached, and the pin keeps them
            // safe from the restage's own host evictions.
            self.nodes[child.0].pinned += 1;
            cur = child;
        }
        self.unpin(&result.path);
        result
    }

    /// Restage one disk-resident node into the host tier. Returns false
    /// when the disk holds no entry for the node, the spans disagree
    /// (the node was re-cached with a different token count after the
    /// spill — the stale entry is discarded rather than served), or
    /// host room cannot be made.
    fn restage_node(
        &mut self,
        id: NodeId,
        transfers: &mut Transfers,
    ) -> bool {
        let tokens = self.nodes[id.0].tokens;
        let key = DiskKey::Node(id);
        match self.disk.as_ref().and_then(|d| d.entry_tokens(key)) {
            Some(t) if t == tokens => {}
            Some(_) => {
                self.disk.as_mut().expect("entry above").discard(key);
                return false;
            }
            None => return false,
        }
        let bytes = self.page.bytes(tokens);
        let payload_bytes = self.page.payload_bytes(tokens);
        // Secure host room BEFORE consuming the disk entry: an unpinned
        // restage frees it, and a failed host reservation must not lose
        // the KV.
        if !self.host.fits_at_all(bytes)
            || !self.ensure_host_space(bytes, None, transfers)
        {
            return false;
        }
        let restaged = self
            .disk
            .as_mut()
            .expect("entry above")
            .restage(key)
            .expect("entry validated above");
        let ok = self.host.alloc(bytes);
        debug_assert!(ok);
        self.set_tier(id, Some(Tier::Host));
        self.nodes[id.0].host_copy = true;
        self.nodes[id.0].payload = restaged.payload;
        transfers.d2h_bytes += payload_bytes;
        self.counters.disk_restage_hits += 1;
        self.counters.disk_restage_bytes += payload_bytes;
        true
    }

    /// Pin every node on `path` (and the root) against eviction for the
    /// duration of a request.
    pub fn pin(&mut self, path: &[NodeId]) {
        for &id in path {
            self.nodes[id.0].pinned += 1;
        }
    }

    pub fn unpin(&mut self, path: &[NodeId]) {
        for &id in path {
            debug_assert!(self.nodes[id.0].pinned > 0);
            self.nodes[id.0].pinned -= 1;
        }
    }

    /// Apply the policy's access update to a node (Algorithm 1
    /// `UPDATE_NODE_IN_GPU`). The tier clock at access time anchors the
    /// priority.
    pub fn on_access(&mut self, id: NodeId, ctx: &AccessCtx) {
        let clock = match self.nodes[id.0].tier {
            Some(Tier::Host) => self.clock_host,
            _ => self.clock_gpu,
        };
        self.policy.on_access(&mut self.nodes[id.0].stats, ctx, clock);
    }

    /// Probe the chunk cache for a doc that missed the prefix walk
    /// (lookup order: prefix walk → chunk probe → miss). A hit pins the
    /// backing entry for the admission's lifetime and reports what to
    /// charge: `tokens − r` reused rows into α, `r` boundary tokens
    /// into β, and the h2g bytes (host-resident entries) that ride the
    /// per-batch H2D burst. `tokens` must match the cached span — a
    /// truncation-policy mismatch is a miss, not a partial hit.
    pub fn chunk_probe(
        &mut self,
        doc: DocId,
        tokens: usize,
    ) -> Option<ChunkHit> {
        let state = self.chunk.as_ref()?;
        let boundary = state.boundary_tokens;
        if tokens <= boundary {
            return None; // nothing reusable beyond the repair cost
        }
        // Validate the slot, then pin through the resolved source.
        let source = match state.slots.get(&doc)? {
            ChunkSlot::Ref(id) => {
                let node = &self.nodes[id.0];
                if node.tier.is_none() || node.tokens != tokens {
                    return None; // stale ref or span mismatch
                }
                ChunkSource::Node(*id)
            }
            ChunkSlot::Owned(e) => {
                if e.doomed || e.tokens != tokens {
                    return None;
                }
                ChunkSource::Owned
            }
        };
        let reused = tokens - boundary;
        let reused_bytes = self.page.payload_bytes(reused);
        let h2g_bytes = match source {
            ChunkSource::Node(id) => match self.nodes[id.0].tier {
                Some(Tier::Gpu) => 0,
                _ => reused_bytes,
            },
            ChunkSource::Owned => {
                match self.chunk.as_ref().and_then(|s| s.slots.get(&doc)) {
                    Some(ChunkSlot::Owned(e)) if e.tier == Tier::Gpu => 0,
                    _ => reused_bytes,
                }
            }
        };
        match source {
            ChunkSource::Node(id) => self.nodes[id.0].pinned += 1,
            ChunkSource::Owned => {
                if let Some(ChunkSlot::Owned(e)) = self
                    .chunk
                    .as_mut()
                    .and_then(|s| s.slots.get_mut(&doc))
                {
                    e.pinned += 1;
                }
            }
        }
        self.counters.chunk_hits += 1;
        self.counters.chunk_hit_bytes += reused_bytes;
        self.counters.boundary_recompute_tokens += boundary as u64;
        Some(ChunkHit {
            doc,
            tokens,
            boundary,
            reused_tokens: reused,
            h2g_bytes,
            source,
        })
    }

    /// Release the pin a [`KnowledgeTree::chunk_probe`] hit took, by the
    /// exact source recorded in the hit — so a registry slot rebound by
    /// a concurrent insert can never unbalance the pin ledger. An owned
    /// entry superseded (`doomed`) while pinned is released here, on its
    /// last unpin.
    pub fn chunk_unpin(&mut self, doc: DocId, source: ChunkSource) {
        match source {
            ChunkSource::Node(id) => {
                debug_assert!(self.nodes[id.0].pinned > 0);
                self.nodes[id.0].pinned -= 1;
            }
            ChunkSource::Owned => {
                let Some(state) = self.chunk.as_mut() else {
                    return;
                };
                let Some(ChunkSlot::Owned(e)) = state.slots.get_mut(&doc)
                else {
                    // Slot force-dropped (GPU failure): pin died with it.
                    return;
                };
                debug_assert!(e.pinned > 0);
                e.pinned -= 1;
                if e.pinned == 0 && e.doomed {
                    let bytes = self.page.bytes(e.tokens);
                    let tier = e.tier;
                    state.slots.remove(&doc);
                    match tier {
                        Tier::Gpu => self.gpu.release(bytes),
                        Tier::Host => self.host.release(bytes),
                    }
                }
            }
        }
    }

    /// Policy access update for a chunk hit (the chunk-aware
    /// replacement score: same [`NodeStats`] machinery, anchored at the
    /// clock of the tier the entry resides in). For tree-backed hits
    /// this refreshes the node's own stats — a doc hot through the
    /// chunk path stays hot in the tree's eviction order too.
    pub fn chunk_on_access(&mut self, hit: &ChunkHit, ctx: &AccessCtx) {
        match hit.source {
            ChunkSource::Node(id) => self.on_access(id, ctx),
            ChunkSource::Owned => {
                let clock_gpu = self.clock_gpu;
                let clock_host = self.clock_host;
                let Some(state) = self.chunk.as_mut() else {
                    return;
                };
                if let Some(ChunkSlot::Owned(e)) =
                    state.slots.get_mut(&hit.doc)
                {
                    let clock = match e.tier {
                        Tier::Gpu => clock_gpu,
                        Tier::Host => clock_host,
                    };
                    self.policy.on_access(&mut e.stats, ctx, clock);
                }
            }
        }
    }

    /// Non-mutating chunk estimate for scheduling priority: would `doc`
    /// hit the chunk cache, and with how many reused/boundary tokens?
    /// Uses the entry's own recorded span (a probe re-validates against
    /// the request's actual token count). Returns
    /// `(reused_tokens, boundary_tokens)`.
    pub fn chunk_estimate(&self, doc: DocId) -> Option<(usize, usize)> {
        let state = self.chunk.as_ref()?;
        let (tokens, live) = match state.slots.get(&doc)? {
            ChunkSlot::Ref(id) => {
                let n = &self.nodes[id.0];
                (n.tokens, n.tier.is_some())
            }
            ChunkSlot::Owned(e) => (e.tokens, !e.doomed),
        };
        if live && tokens > state.boundary_tokens {
            Some((
                tokens - state.boundary_tokens,
                state.boundary_tokens,
            ))
        } else {
            None
        }
    }

    /// KV rows backing a chunk entry (None in accounting-only mode, or
    /// when the doc has no live entry). Real-path prefill splices rows
    /// `[boundary..]` of this payload behind the prefix KV.
    pub fn chunk_payload(&self, doc: DocId) -> Option<&KvPayload> {
        match self.chunk.as_ref()?.slots.get(&doc)? {
            ChunkSlot::Ref(id) => self.nodes[id.0].payload.as_ref(),
            ChunkSlot::Owned(e) => e.payload.as_ref(),
        }
    }

    /// Cache a document as an OWNED chunk entry — the commit path for a
    /// prefilled doc the tree rejected (no GPU room on its prefix
    /// path). Charged against the shared tiers: GPU first, host as
    /// fallback, evicting lower-priority residents (tree nodes AND
    /// chunk entries) exactly like a leaf insert; eviction transfers
    /// merge into `transfers`. `rope_offset` records the position the
    /// KV was computed at. Returns whether the entry was cached.
    pub fn chunk_insert_owned(
        &mut self,
        doc: DocId,
        tokens: usize,
        rope_offset: usize,
        payload: Option<KvPayload>,
        transfers: &mut Transfers,
    ) -> bool {
        let Some(state) = self.chunk.as_ref() else {
            return false;
        };
        if tokens <= state.boundary_tokens {
            return false; // reuse would save nothing
        }
        match state.slots.get(&doc) {
            // Live entry already serves this doc (dedupe), or a doomed
            // one still holds bytes until its last unpin — never stack
            // a second allocation on the same slot.
            Some(ChunkSlot::Owned(_)) => return false,
            Some(ChunkSlot::Ref(id))
                if self.nodes[id.0].tier.is_some() =>
            {
                return false;
            }
            _ => {}
        }
        let bytes = self.page.bytes(tokens);
        let tier = if self.gpu.fits_at_all(bytes)
            && self.ensure_gpu_space(bytes, transfers)
        {
            let ok = self.gpu.alloc(bytes);
            debug_assert!(ok);
            Tier::Gpu
        } else if self.host.fits_at_all(bytes)
            && self.ensure_host_space(bytes, None, transfers)
        {
            let ok = self.host.alloc(bytes);
            debug_assert!(ok);
            Tier::Host
        } else {
            return false;
        };
        self.chunk.as_mut().expect("checked above").slots.insert(
            doc,
            ChunkSlot::Owned(ChunkEntry {
                tokens,
                rope_offset,
                tier,
                pinned: 0,
                doomed: false,
                stats: NodeStats::default(),
                payload,
            }),
        );
        true
    }

    /// Restage a demoted (or CAG-prestaged) chunk entry for `doc` from
    /// the disk tier into a host-resident OWNED entry, so an immediate
    /// re-probe hits. Pinned (corpus-pinned) disk entries are restaged
    /// by copy and stay on disk; unpinned ones move. `tokens` must
    /// match the cached span — a truncation-policy mismatch is a miss.
    /// Returns whether an entry was restaged; the `d2h` bytes merge
    /// into `transfers` for the per-batch staged-read burst.
    pub fn chunk_restage(
        &mut self,
        doc: DocId,
        tokens: usize,
        transfers: &mut Transfers,
    ) -> bool {
        let Some(state) = self.chunk.as_ref() else {
            return false;
        };
        if tokens <= state.boundary_tokens {
            return false;
        }
        // Same dedupe rules as chunk_insert_owned: never stack on a
        // live or doomed slot; a stale Ref is overwritten below.
        match state.slots.get(&doc) {
            Some(ChunkSlot::Owned(_)) => return false,
            Some(ChunkSlot::Ref(id))
                if self.nodes[id.0].tier.is_some() =>
            {
                return false;
            }
            _ => {}
        }
        let key = DiskKey::Chunk(doc);
        match self.disk.as_ref().and_then(|d| d.entry_tokens(key)) {
            Some(t) if t == tokens => {}
            _ => return false,
        }
        let bytes = self.page.bytes(tokens);
        let payload_bytes = self.page.payload_bytes(tokens);
        // Host room first (see restage_node: a failed reservation must
        // not have consumed the entry).
        if !self.host.fits_at_all(bytes)
            || !self.ensure_host_space(bytes, None, transfers)
        {
            return false;
        }
        let restaged = self
            .disk
            .as_mut()
            .expect("entry above")
            .restage(key)
            .expect("entry validated above");
        let ok = self.host.alloc(bytes);
        debug_assert!(ok);
        self.chunk.as_mut().expect("checked above").slots.insert(
            doc,
            ChunkSlot::Owned(ChunkEntry {
                tokens,
                rope_offset: restaged.rope_offset,
                tier: Tier::Host,
                pinned: 0,
                doomed: false,
                stats: NodeStats::default(),
                payload: restaged.payload,
            }),
        );
        transfers.d2h_bytes += payload_bytes;
        self.counters.disk_restage_hits += 1;
        self.counters.disk_restage_bytes += payload_bytes;
        true
    }

    /// CAG corpus pre-staging: park `doc`'s KV in the disk tier as a
    /// PINNED entry — pinned entries are restaged by copy and never
    /// freed, so the corpus survives any cache pressure and every later
    /// touch is a hit. With the disk tier off, falls back to a
    /// best-effort owned chunk entry in GPU/host (evictable, but warm).
    /// Startup staging: nothing is charged. Returns whether the doc is
    /// now pinned on disk (or cached via the fallback).
    pub fn prestage_corpus_doc(
        &mut self,
        doc: DocId,
        tokens: usize,
        rope_offset: usize,
        payload: Option<KvPayload>,
    ) -> bool {
        let Some(state) = self.chunk.as_ref() else {
            return false; // CAG rides the chunk registry
        };
        if tokens <= state.boundary_tokens {
            return false;
        }
        let bytes = self.page.bytes(tokens);
        if let Some(disk) = self.disk.as_mut() {
            return disk.spill(
                DiskKey::Chunk(doc),
                tokens,
                rope_offset,
                bytes,
                payload,
                true,
            ) != SpillOutcome::NoRoom;
        }
        let mut startup = Transfers::default();
        self.chunk_insert_owned(
            doc,
            tokens,
            rope_offset,
            payload,
            &mut startup,
        )
    }

    /// Dedupe hook on every successful tree insert of `doc`: the chunk
    /// registry now shares the node's payload (zero-byte `Ref`). An
    /// owned entry for the same doc is released immediately, or marked
    /// doomed until its in-flight pins drain — a doc is charged against
    /// the tiers either as a tree node or as an owned chunk entry,
    /// never both.
    fn chunk_note_insert(&mut self, doc: DocId, id: NodeId) {
        let page = self.page;
        let Some(state) = self.chunk.as_mut() else {
            return;
        };
        // Inspect first, act second (get_mut + insert in one match is
        // the borrow pattern NLL rejects).
        if matches!(
            state.slots.get(&doc),
            Some(ChunkSlot::Owned(e)) if e.pinned > 0
        ) {
            if let Some(ChunkSlot::Owned(e)) = state.slots.get_mut(&doc) {
                e.doomed = true; // released on last unpin
            }
            return;
        }
        let released = match state.slots.get(&doc) {
            Some(ChunkSlot::Owned(e)) => {
                Some((page.bytes(e.tokens), e.tier))
            }
            _ => None,
        };
        state.slots.insert(doc, ChunkSlot::Ref(id));
        if let Some((bytes, tier)) = released {
            match tier {
                Tier::Gpu => self.gpu.release(bytes),
                Tier::Host => self.host.release(bytes),
            }
        }
    }

    /// Bring every host-resident node of `path` into GPU (cache-hit
    /// loading, §3.2). Nodes are promoted root-to-leaf to preserve the
    /// hierarchy; `path` is already in that order. Promotion stops at the
    /// first node GPU space cannot be made for; the returned
    /// [`Promotion`] carries the usable prefix length AND the transfers
    /// of everything that moved before the stop — a mid-path failure
    /// must never lose the h2g/g2h bytes already spent.
    pub fn promote(&mut self, path: &[NodeId]) -> Promotion {
        let mut transfers = Transfers::default();
        // Pin the whole path first: making room for one node must not
        // evict another node of the same path (or the path itself).
        self.pin(path);
        let mut promoted = path.len();
        for (i, &id) in path.iter().enumerate() {
            if self.nodes[id.0].tier == Some(Tier::Gpu) {
                continue;
            }
            debug_assert_eq!(self.nodes[id.0].tier, Some(Tier::Host));
            let bytes = self.page.bytes(self.nodes[id.0].tokens);
            if !self.ensure_gpu_space(bytes, &mut transfers) {
                promoted = i;
                break;
            }
            let ok = self.gpu.alloc(bytes);
            debug_assert!(ok);
            // Swap-out-only-once: host copy is retained.
            self.set_tier(id, Some(Tier::Gpu));
            transfers.h2g_bytes +=
                self.page.payload_bytes(self.nodes[id.0].tokens);
        }
        self.unpin(path);
        Promotion {
            transfers,
            promoted,
        }
    }

    /// Insert (or find) the child of `parent` for `doc`, cached in GPU
    /// with the given token count. Returns the transfers performed —
    /// charged even when insertion fails partway, since ancestor
    /// promotion and eviction work is real byte movement — and the node,
    /// or None if the document cannot fit (left uncached — the paper's
    /// transient oversized request case).
    pub fn insert_child(
        &mut self,
        parent: NodeId,
        doc: DocId,
        tokens: usize,
        payload: Option<KvPayload>,
    ) -> (Transfers, Option<NodeId>) {
        // A GPU-resident child requires a GPU-resident ancestor chain
        // (hierarchical partition): promote the parent path first.
        let mut up = Vec::new();
        let mut cur = Some(parent);
        while let Some(id) = cur {
            if self.nodes[id.0].tier.is_none() {
                // Ancestor fully evicted: path invalid.
                return (Transfers::default(), None);
            }
            up.push(id);
            cur = self.nodes[id.0].parent;
        }
        up.reverse();
        let promo = self.promote(&up);
        let mut transfers = promo.transfers;
        if !promo.complete(up.len()) {
            return (transfers, None);
        }
        // Pin the ancestor chain so making room for the child cannot
        // evict its own parents.
        self.pin(&up);
        let result = self.insert_child_pinned(
            parent,
            doc,
            tokens,
            payload,
            &mut transfers,
        );
        self.unpin(&up);
        (transfers, result)
    }

    fn insert_child_pinned(
        &mut self,
        parent: NodeId,
        doc: DocId,
        tokens: usize,
        payload: Option<KvPayload>,
        transfers: &mut Transfers,
    ) -> Option<NodeId> {
        if let Some(&existing) = self.nodes[parent.0].children.get(&doc) {
            if self.nodes[existing.0].tier.is_some() {
                return Some(existing);
            }
            // Re-cache a skeleton node (token count may have changed,
            // e.g. a different truncation policy — the new value wins).
            // The node is mutated only once GPU space is secured: a
            // failed insert must leave the skeleton exactly as it was,
            // not carrying a token count from an insert that never
            // happened.
            let bytes = self.page.bytes(tokens);
            if !self.gpu.fits_at_all(bytes)
                || !self.ensure_gpu_space(bytes, transfers)
            {
                self.counters.rejected_inserts += 1;
                return None;
            }
            let ok = self.gpu.alloc(bytes);
            debug_assert!(ok);
            if self.nodes[existing.0].tokens != tokens {
                // The node's content changes: every descendant's disk
                // KV was computed in the OLD ancestor context and is
                // now stale — drop the whole subtree's entries (its
                // own included) rather than ever serving wrong KV.
                self.discard_stale_subtree_disk(existing);
            }
            self.nodes[existing.0].tokens = tokens;
            self.set_tier(existing, Some(Tier::Gpu));
            self.nodes[existing.0].payload = payload;
            self.counters.inserts += 1;
            self.chunk_note_insert(doc, existing);
            return Some(existing);
        }

        let bytes = self.page.bytes(tokens);
        if !self.gpu.fits_at_all(bytes) {
            self.counters.rejected_inserts += 1;
            return None;
        }
        if !self.ensure_gpu_space(bytes, transfers) {
            self.counters.rejected_inserts += 1;
            return None;
        }
        let ok = self.gpu.alloc(bytes);
        debug_assert!(ok);
        let id = NodeId(self.nodes.len());
        self.nodes.push(Node {
            doc,
            parent: Some(parent),
            children: BTreeMap::new(),
            tokens,
            tier: Some(Tier::Gpu),
            host_copy: false,
            pinned: 0,
            stats: NodeStats::default(),
            payload,
        });
        self.nodes[parent.0].children.insert(doc, id);
        self.gpu_resident.insert(id.0);
        self.counters.inserts += 1;
        self.chunk_note_insert(doc, id);
        Some(id)
    }

    /// Drop the disk entries of `id` and its whole descendant subtree:
    /// called when a skeleton re-cache changes `id`'s token count,
    /// invalidating every descendant's position-dependent KV.
    fn discard_stale_subtree_disk(&mut self, id: NodeId) {
        let Some(disk) = self.disk.as_mut() else {
            return;
        };
        let mut stack = vec![id];
        while let Some(n) = stack.pop() {
            disk.discard(DiskKey::Node(n));
            stack.extend(self.nodes[n.0].children.values().copied());
        }
    }

    /// Make at least `bytes` available in the GPU tier by evicting
    /// leaf-frontier nodes (Algorithm 1 `EVICT_IN_GPU`), merging every
    /// transfer performed into `transfers` — evictions that precede an
    /// eventual failure still moved real bytes and must stay charged.
    /// Returns false if the space cannot be made (everything pinned).
    pub fn ensure_gpu_space(
        &mut self,
        bytes: u64,
        transfers: &mut Transfers,
    ) -> bool {
        while self.gpu.free() < bytes {
            if !self.evict_one_gpu(transfers) {
                return false;
            }
        }
        true
    }

    /// Evict exactly one GPU resident, letting tree leaf-frontier nodes
    /// and owned chunk entries COMPETE on replacement priority (the
    /// chunk-aware policy): whichever candidate scores lower goes. With
    /// the chunk cache off this reduces to exactly the node-only path.
    fn evict_one_gpu(&mut self, transfers: &mut Transfers) -> bool {
        let node = self.pick_gpu_victim();
        let chunk = self.pick_gpu_chunk_victim();
        match (node, chunk) {
            (Some(id), Some((cp, doc))) => {
                let np = self.policy.priority(&self.nodes[id.0].stats);
                // Strictly-lower only: ties keep the tree node (prefix
                // reuse is positionally stronger than chunk reuse).
                if cp < np {
                    self.evict_gpu_chunk(doc, transfers);
                } else {
                    transfers.merge(self.evict_gpu_node(id));
                }
                true
            }
            (Some(id), None) => {
                transfers.merge(self.evict_gpu_node(id));
                true
            }
            (None, Some((_, doc))) => {
                self.evict_gpu_chunk(doc, transfers);
                true
            }
            (None, None) => false,
        }
    }

    /// Host-tier counterpart of [`KnowledgeTree::evict_one_gpu`].
    /// `exclude` protects the node currently being swapped out. Disk
    /// demotions performed by the eviction record their `h2d` bytes in
    /// `transfers`.
    fn evict_one_host(
        &mut self,
        exclude: Option<NodeId>,
        transfers: &mut Transfers,
    ) -> bool {
        let node = self.pick_host_victim(exclude);
        let chunk = self.pick_host_chunk_victim();
        match (node, chunk) {
            (Some(id), Some((cp, doc))) => {
                let np = self.policy.priority(&self.nodes[id.0].stats);
                if cp < np {
                    self.evict_host_chunk(doc, transfers);
                } else {
                    self.evict_host_node(id, transfers);
                }
                true
            }
            (Some(id), None) => {
                self.evict_host_node(id, transfers);
                true
            }
            (None, Some((_, doc))) => {
                self.evict_host_chunk(doc, transfers);
                true
            }
            (None, None) => false,
        }
    }

    /// Make at least `bytes` free in the host tier (host-side analogue
    /// of [`KnowledgeTree::ensure_gpu_space`]).
    fn ensure_host_space(
        &mut self,
        bytes: u64,
        exclude: Option<NodeId>,
        transfers: &mut Transfers,
    ) -> bool {
        while self.host.free() < bytes {
            if !self.evict_one_host(exclude, transfers) {
                return false;
            }
        }
        true
    }

    /// Lowest-priority unpinned GPU-resident OWNED chunk entry.
    fn pick_gpu_chunk_victim(&self) -> Option<(f64, DocId)> {
        let state = self.chunk.as_ref()?;
        let mut best: Option<(f64, DocId)> = None;
        for (&doc, slot) in &state.slots {
            let ChunkSlot::Owned(e) = slot else { continue };
            if e.tier != Tier::Gpu || e.pinned > 0 {
                continue;
            }
            let p = self.policy.priority(&e.stats);
            if best.map_or(true, |(bp, _)| p < bp) {
                best = Some((p, doc));
            }
        }
        best
    }

    /// Lowest-priority unpinned host-resident OWNED chunk entry.
    fn pick_host_chunk_victim(&self) -> Option<(f64, DocId)> {
        let state = self.chunk.as_ref()?;
        let mut best: Option<(f64, DocId)> = None;
        for (&doc, slot) in &state.slots {
            let ChunkSlot::Owned(e) = slot else { continue };
            if e.tier != Tier::Host || e.pinned > 0 {
                continue;
            }
            let p = self.policy.priority(&e.stats);
            if best.map_or(true, |(bp, _)| p < bp) {
                best = Some((p, doc));
            }
        }
        best
    }

    /// Evict one GPU-resident owned chunk entry: swap to host when room
    /// can be made (the g2h bytes merge into `transfers` like a node
    /// swap-out), drop entirely otherwise. Advances the GPU clock.
    fn evict_gpu_chunk(&mut self, doc: DocId, transfers: &mut Transfers) {
        let (tokens, priority) =
            match self.chunk.as_ref().and_then(|s| s.slots.get(&doc)) {
                Some(ChunkSlot::Owned(e)) if e.tier == Tier::Gpu => {
                    (e.tokens, self.policy.priority(&e.stats))
                }
                _ => return,
            };
        let bytes = self.page.bytes(tokens);
        let payload_bytes = self.page.payload_bytes(tokens);
        self.clock_gpu = self.clock_gpu.max(priority);
        if self.host.fits_at_all(bytes)
            && self.ensure_host_space(bytes, None, transfers)
        {
            let ok = self.host.alloc(bytes);
            debug_assert!(ok);
            self.gpu.release(bytes);
            if let Some(ChunkSlot::Owned(e)) = self
                .chunk
                .as_mut()
                .and_then(|s| s.slots.get_mut(&doc))
            {
                e.tier = Tier::Host;
            }
            transfers.g2h_bytes += payload_bytes;
            self.counters.swap_out_bytes += payload_bytes;
        } else {
            // Host cannot absorb it: demote GPU→disk when the third
            // tier has room, drop otherwise (the pre-disk behavior).
            self.gpu.release(bytes);
            let entry = match self.chunk.as_mut() {
                Some(state) => state.slots.remove(&doc),
                None => None,
            };
            if let Some(ChunkSlot::Owned(e)) = entry {
                self.spill_chunk_entry(doc, e, bytes, transfers);
            }
        }
        self.counters.gpu_evictions += 1;
    }

    /// Evict one host-resident owned chunk entry: demote it to the disk
    /// tier when the budget has room, drop it otherwise. Advances the
    /// host clock.
    fn evict_host_chunk(&mut self, doc: DocId, transfers: &mut Transfers) {
        let (tokens, priority) =
            match self.chunk.as_ref().and_then(|s| s.slots.get(&doc)) {
                Some(ChunkSlot::Owned(e)) if e.tier == Tier::Host => {
                    (e.tokens, self.policy.priority(&e.stats))
                }
                _ => return,
            };
        let bytes = self.page.bytes(tokens);
        self.clock_host = self.clock_host.max(priority);
        self.host.release(bytes);
        let entry = match self.chunk.as_mut() {
            Some(state) => state.slots.remove(&doc),
            None => None,
        };
        if let Some(ChunkSlot::Owned(e)) = entry {
            self.spill_chunk_entry(doc, e, bytes, transfers);
        }
        self.counters.host_evictions += 1;
    }

    /// Demote a removed owned chunk entry to the disk tier, recording
    /// the spill; a refused spill (disk off / no room) drops the KV
    /// exactly like the pre-disk path.
    fn spill_chunk_entry(
        &mut self,
        doc: DocId,
        e: ChunkEntry,
        bytes: u64,
        transfers: &mut Transfers,
    ) {
        let payload_bytes = self.page.payload_bytes(e.tokens);
        let Some(disk) = self.disk.as_mut() else {
            return;
        };
        if disk.spill(
            DiskKey::Chunk(doc),
            e.tokens,
            e.rope_offset,
            bytes,
            e.payload,
            false,
        ) == SpillOutcome::Stored
        {
            transfers.h2d_bytes += payload_bytes;
            self.counters.disk_spills += 1;
            self.counters.disk_spill_bytes += payload_bytes;
        }
    }

    /// GPU leaf frontier: GPU-resident, unpinned, no GPU-resident child
    /// (Algorithm 1 line 17), minimum priority (line 19).
    fn pick_gpu_victim(&self) -> Option<NodeId> {
        let mut best: Option<(f64, NodeId)> = None;
        for &i in &self.gpu_resident {
            let node = &self.nodes[i];
            if node.pinned > 0 {
                continue;
            }
            let has_gpu_child = node
                .children
                .values()
                .any(|&c| self.nodes[c.0].tier == Some(Tier::Gpu));
            if has_gpu_child {
                continue;
            }
            let p = self.policy.priority(&node.stats);
            if best.map_or(true, |(bp, _)| p < bp) {
                best = Some((p, NodeId(i)));
            }
        }
        best.map(|(_, id)| id)
    }

    /// Evict one GPU node: swap to host on first eviction, zero-copy free
    /// afterwards (§5.1 swap-out-only-once). Advances the GPU clock
    /// (Eq. 2).
    fn evict_gpu_node(&mut self, id: NodeId) -> Transfers {
        let mut transfers = Transfers::default();
        let bytes = self.page.bytes(self.nodes[id.0].tokens);
        let payload_bytes = self.page.payload_bytes(self.nodes[id.0].tokens);

        let needs_copy =
            !(self.swap_out_only_once && self.nodes[id.0].host_copy);
        if needs_copy {
            // Find host space (may cascade host evictions of nodes and
            // chunk entries alike); too big for host entirely, or host
            // cannot make room → demote straight to the disk tier when
            // it has room, drop from cache otherwise.
            if !self.host.fits_at_all(bytes)
                || !self.ensure_host_space(bytes, Some(id), &mut transfers)
            {
                if !self.demote_gpu_to_disk(id, &mut transfers) {
                    self.drop_from_gpu(id);
                }
                return transfers;
            }
            let ok = self.host.alloc(bytes);
            debug_assert!(ok);
            self.nodes[id.0].host_copy = true;
            transfers.g2h_bytes += payload_bytes;
            self.counters.swap_out_bytes += payload_bytes;
        } else {
            self.counters.zero_copy_evictions += 1;
        }

        self.clock_gpu = self
            .clock_gpu
            .max(self.policy.priority(&self.nodes[id.0].stats));
        self.set_tier(id, Some(Tier::Host));
        self.gpu.release(bytes);
        self.counters.gpu_evictions += 1;
        transfers
    }

    /// Demote a GPU node straight to the disk tier when the host cannot
    /// absorb its swap-out (the GPU → disk shortcut of the cascade).
    /// Returns false when the disk tier is off or refuses the bytes —
    /// the caller then drops the node outright, exactly as pre-disk.
    fn demote_gpu_to_disk(
        &mut self,
        id: NodeId,
        transfers: &mut Transfers,
    ) -> bool {
        if self.disk.is_none() {
            return false;
        }
        let tokens = self.nodes[id.0].tokens;
        let bytes = self.page.bytes(tokens);
        let payload_bytes = self.page.payload_bytes(tokens);
        let payload = self.nodes[id.0].payload.take();
        let disk = self.disk.as_mut().expect("checked above");
        let outcome =
            disk.spill(DiskKey::Node(id), tokens, 0, bytes, payload, false);
        if outcome == SpillOutcome::NoRoom {
            // Payload is gone either way: the drop path clears it too.
            return false;
        }
        self.clock_gpu = self
            .clock_gpu
            .max(self.policy.priority(&self.nodes[id.0].stats));
        if self.nodes[id.0].host_copy {
            self.host.release(bytes);
            self.nodes[id.0].host_copy = false;
        }
        self.set_tier(id, None);
        self.gpu.release(bytes);
        self.counters.gpu_evictions += 1;
        if outcome == SpillOutcome::Stored {
            transfers.h2d_bytes += payload_bytes;
            self.counters.disk_spills += 1;
            self.counters.disk_spill_bytes += payload_bytes;
        }
        true
    }

    /// Evict a GPU node without keeping any copy (host has no room).
    fn drop_from_gpu(&mut self, id: NodeId) {
        let bytes = self.page.bytes(self.nodes[id.0].tokens);
        self.clock_gpu = self
            .clock_gpu
            .max(self.policy.priority(&self.nodes[id.0].stats));
        if self.nodes[id.0].host_copy {
            self.host.release(bytes);
            self.nodes[id.0].host_copy = false;
        }
        self.set_tier(id, None);
        self.nodes[id.0].payload = None;
        self.gpu.release(bytes);
        self.counters.gpu_evictions += 1;
    }

    /// Host leaf frontier: host-resident, unpinned, no cached child at
    /// all. `exclude` protects the node currently being swapped out.
    fn pick_host_victim(&self, exclude: Option<NodeId>) -> Option<NodeId> {
        let mut best: Option<(f64, NodeId)> = None;
        for &i in &self.host_resident {
            let node = &self.nodes[i];
            if node.pinned > 0 || exclude == Some(NodeId(i)) {
                continue;
            }
            let has_cached_child = node
                .children
                .values()
                .any(|&c| self.nodes[c.0].tier.is_some());
            if has_cached_child {
                continue;
            }
            let p = self.policy.priority(&node.stats);
            if best.map_or(true, |(bp, _)| p < bp) {
                best = Some((p, NodeId(i)));
            }
        }
        best.map(|(_, id)| id)
    }

    /// Evict a node from the host tier: demote its KV to the disk tier
    /// when the budget has room (the host → disk leg of the cascade),
    /// remove it from the cache entirely otherwise. Advances the host
    /// clock. The demotion's `h2d` bytes merge into `transfers` — they
    /// ride the async staging queue, counted but never charged.
    fn evict_host_node(&mut self, id: NodeId, transfers: &mut Transfers) {
        debug_assert_eq!(self.nodes[id.0].tier, Some(Tier::Host));
        let tokens = self.nodes[id.0].tokens;
        let bytes = self.page.bytes(tokens);
        let payload_bytes = self.page.payload_bytes(tokens);
        self.clock_host = self
            .clock_host
            .max(self.policy.priority(&self.nodes[id.0].stats));
        self.host.release(bytes);
        self.set_tier(id, None);
        self.nodes[id.0].host_copy = false;
        if self.disk.is_some() {
            let payload = self.nodes[id.0].payload.take();
            let disk = self.disk.as_mut().expect("checked above");
            if disk.spill(
                DiskKey::Node(id),
                tokens,
                0,
                bytes,
                payload,
                false,
            ) == SpillOutcome::Stored
            {
                transfers.h2d_bytes += payload_bytes;
                self.counters.disk_spills += 1;
                self.counters.disk_spill_bytes += payload_bytes;
            }
        }
        self.nodes[id.0].payload = None;
        self.counters.host_evictions += 1;
    }

    /// Current logical clocks `(gpu, host)` — exposed for tests and the
    /// scheduling-time bench.
    pub fn clocks(&self) -> (f64, f64) {
        (self.clock_gpu, self.clock_host)
    }

    /// Nodes currently pinned by in-flight requests, excluding the root's
    /// permanent pin — must return to zero once every admission has been
    /// committed or released (checked by the concurrency tests).
    pub fn pinned_nodes(&self) -> usize {
        let chunk_pins = self.chunk.as_ref().map_or(0, |s| {
            s.slots
                .values()
                .filter(|slot| {
                    matches!(slot, ChunkSlot::Owned(e) if e.pinned > 0)
                })
                .count()
        });
        self.nodes
            .iter()
            .enumerate()
            .filter(|&(i, n)| {
                if NodeId(i) == self.root {
                    n.pinned > 1
                } else {
                    n.pinned > 0
                }
            })
            .count()
            + chunk_pins
    }

    /// Validate every structural invariant; used by property tests.
    /// Panics with a description on violation.
    pub fn check_invariants(&self) {
        let mut gpu_bytes = 0u64;
        let mut host_bytes = 0u64;
        for (i, node) in self.nodes.iter().enumerate() {
            let bytes = self.page.bytes(node.tokens);
            if node.tier == Some(Tier::Gpu) {
                gpu_bytes += bytes;
            }
            if node.host_copy || node.tier == Some(Tier::Host) {
                host_bytes += bytes;
            }
            if node.tier == Some(Tier::Host) {
                assert!(
                    node.host_copy,
                    "node {i}: host tier implies host copy"
                );
            }
            // Hierarchy: cached node's parent is cached in >= tier.
            if let (Some(tier), Some(parent)) = (node.tier, node.parent) {
                let pt = self.nodes[parent.0].tier;
                match tier {
                    Tier::Gpu => assert_eq!(
                        pt,
                        Some(Tier::Gpu),
                        "node {i}: GPU node's parent must be GPU"
                    ),
                    Tier::Host => assert!(
                        pt.is_some(),
                        "node {i}: host node's parent must be cached"
                    ),
                }
            }
            // Parent/child coherence.
            for (&doc, &child) in &node.children {
                assert_eq!(self.nodes[child.0].doc, doc);
                assert_eq!(self.nodes[child.0].parent, Some(NodeId(i)));
            }
            if let Some(p) = &node.payload {
                assert_eq!(
                    p.tokens(),
                    node.tokens,
                    "node {i}: payload token mismatch"
                );
            }
        }
        // Owned chunk entries hold tier bytes of their own (including
        // doomed-but-pinned ones, whose bytes drain on last unpin);
        // Refs are zero-byte by construction — this is the per-tier
        // `used ≤ Σ distinct payloads` dedupe guarantee.
        if let Some(state) = &self.chunk {
            for (doc, slot) in &state.slots {
                if let ChunkSlot::Owned(e) = slot {
                    assert!(
                        !(e.doomed && e.pinned == 0),
                        "chunk {doc}: doomed entry must be pin-held"
                    );
                    let bytes = self.page.bytes(e.tokens);
                    match e.tier {
                        Tier::Gpu => gpu_bytes += bytes,
                        Tier::Host => host_bytes += bytes,
                    }
                    if let Some(p) = &e.payload {
                        assert_eq!(
                            p.tokens(),
                            e.tokens,
                            "chunk {doc}: payload token mismatch"
                        );
                    }
                }
            }
        }
        assert_eq!(gpu_bytes, self.gpu.used(), "gpu accounting");
        assert_eq!(host_bytes, self.host.used(), "host accounting");
        // Disk tier: internal slot/byte accounting, plus every
        // node-keyed entry must still describe its node's span (stale
        // spans are discarded at re-cache / restage time). An entry may
        // coexist with a cached node — the disk analogue of the
        // swap-out-only-once host copy.
        if let Some(disk) = &self.disk {
            disk.check_invariants();
            for key in disk.keys() {
                if let DiskKey::Node(id) = key {
                    assert!(
                        id.0 < self.nodes.len(),
                        "disk node key in arena range"
                    );
                    assert_eq!(
                        disk.entry_tokens(key),
                        Some(self.nodes[id.0].tokens),
                        "disk node entry span matches its node"
                    );
                }
            }
        }
        // Residency indexes agree with node state.
        for (i, node) in self.nodes.iter().enumerate() {
            assert_eq!(
                self.gpu_resident.contains(&i),
                node.tier == Some(Tier::Gpu),
                "gpu index for node {i}"
            );
            assert_eq!(
                self.host_resident.contains(&i),
                node.tier == Some(Tier::Host),
                "host index for node {i}"
            );
        }
    }

    /// Fault tolerance (§6): proactively keep a host copy of a
    /// GPU-resident node so a GPU failure does not lose it. Returns false
    /// if host space cannot be made.
    pub fn replicate_to_host(&mut self, id: NodeId) -> bool {
        if self.nodes[id.0].host_copy
            || self.nodes[id.0].tier != Some(Tier::Gpu)
        {
            return self.nodes[id.0].host_copy;
        }
        let bytes = self.page.bytes(self.nodes[id.0].tokens);
        // Demotions cascaded by the replication are async spills:
        // counted in the tree counters, never charged as latency — the
        // per-op transfers can be dropped here without losing anything
        // the replication caller would bill.
        let mut spills = Transfers::default();
        if !self.host.fits_at_all(bytes)
            || !self.ensure_host_space(bytes, None, &mut spills)
        {
            return false;
        }
        let ok = self.host.alloc(bytes);
        debug_assert!(ok);
        self.nodes[id.0].host_copy = true;
        true
    }

    /// The `n` most frequently accessed GPU-resident nodes closest to the
    /// root — the §6 replication candidates ("most frequently accessed
    /// upper-level nodes").
    pub fn hot_upper_nodes(&self, n: usize) -> Vec<NodeId> {
        let mut cands: Vec<(u64, usize, NodeId)> = Vec::new();
        for (i, node) in self.nodes.iter().enumerate() {
            if i == self.root.0 || node.tier != Some(Tier::Gpu) {
                continue;
            }
            let mut depth = 0usize;
            let mut cur = node.parent;
            while let Some(p) = cur {
                depth += 1;
                cur = self.nodes[p.0].parent;
            }
            cands.push((node.stats.frequency, depth, NodeId(i)));
        }
        // Highest frequency first, shallower first on ties.
        cands.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        cands.into_iter().take(n).map(|(_, _, id)| id).collect()
    }

    /// Simulate a GPU failure (§6): every GPU-resident node without a
    /// host copy is lost; replicated nodes fall back to the host tier.
    /// Returns `(lost, recovered)` node counts.
    pub fn fail_gpu(&mut self) -> (usize, usize) {
        let mut lost = 0;
        let mut recovered = 0;
        // GPU-resident owned chunk entries die with the device (they
        // have no swap-out-only-once host copy); in-flight pins die
        // with them — chunk_unpin tolerates the missing slot.
        let page = self.page;
        if let Some(state) = self.chunk.as_mut() {
            let gone: Vec<(DocId, usize)> = state
                .slots
                .iter()
                .filter_map(|(&d, s)| match s {
                    ChunkSlot::Owned(e) if e.tier == Tier::Gpu => {
                        Some((d, e.tokens))
                    }
                    _ => None,
                })
                .collect();
            for (d, tokens) in gone {
                state.slots.remove(&d);
                self.gpu.release(page.bytes(tokens));
                lost += 1;
            }
        }
        // Process bottom-up so hierarchy checks hold: repeatedly take GPU
        // leaves.
        loop {
            let mut changed = false;
            for i in 0..self.nodes.len() {
                if NodeId(i) == self.root {
                    continue;
                }
                if self.nodes[i].tier != Some(Tier::Gpu) {
                    continue;
                }
                let has_gpu_child = self.nodes[i]
                    .children
                    .values()
                    .any(|&c| self.nodes[c.0].tier == Some(Tier::Gpu));
                if has_gpu_child {
                    continue;
                }
                let bytes = self.page.bytes(self.nodes[i].tokens);
                self.gpu.release(bytes);
                if self.nodes[i].host_copy {
                    self.set_tier(NodeId(i), Some(Tier::Host));
                    recovered += 1;
                } else {
                    self.set_tier(NodeId(i), None);
                    self.nodes[i].payload = None;
                    lost += 1;
                }
                changed = true;
            }
            if !changed {
                break;
            }
        }
        // Hierarchy repair: a host node whose ancestors were lost is
        // unreachable as a prefix — drop it (prefix sensitivity, §6:
        // "a GPU failure would invalidate the lower-level nodes").
        loop {
            let mut dropped = false;
            for i in 0..self.nodes.len() {
                if self.nodes[i].tier != Some(Tier::Host) {
                    continue;
                }
                let parent_ok = match self.nodes[i].parent {
                    None => true,
                    Some(p) => {
                        p == self.root || self.nodes[p.0].tier.is_some()
                    }
                };
                if !parent_ok {
                    let bytes = self.page.bytes(self.nodes[i].tokens);
                    self.host.release(bytes);
                    self.set_tier(NodeId(i), None);
                    self.nodes[i].host_copy = false;
                    self.nodes[i].payload = None;
                    lost += 1;
                    dropped = true;
                }
            }
            if !dropped {
                break;
            }
        }
        (lost, recovered)
    }

    /// Reset frequency statistics (paper: frequency is windowed, reset on
    /// cache clearance).
    pub fn reset_frequencies(&mut self) {
        for node in &mut self.nodes {
            node.stats.frequency = 0;
        }
        if let Some(state) = self.chunk.as_mut() {
            for slot in state.slots.values_mut() {
                if let ChunkSlot::Owned(e) = slot {
                    e.stats.frequency = 0;
                }
            }
        }
    }

    /// All cached `(doc path)` leaves — debugging/inspection helper.
    pub fn cached_doc_count(&self) -> usize {
        self.nodes
            .iter()
            .skip(1)
            .filter(|n| n.tier.is_some())
            .count()
    }
}

#[cfg(test)]
mod tests;
