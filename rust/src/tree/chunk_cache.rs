//! Chunk-level, position-independent KV reuse beside the knowledge tree
//! (Cache-Craft, arxiv 2502.15734).
//!
//! The prefix tree reuses a document's KV only when the document recurs
//! as the *same prefix*; any reordering of the retrieved top-k is a full
//! miss. The chunk cache is a per-document registry layered beside the
//! tree: a retrieved document that misses the prefix walk can reuse a
//! cached chunk entry at *any* position, re-prefilling only the first
//! `r` boundary tokens whose cross-attention the new context invalidates
//! (`r` = `boundary_tokens`, the `--boundary-tokens` CLI knob).
//!
//! Residency and budgets are shared with the tree: an [`ChunkEntry`]
//! that OWNS its KV charges the same GPU/host `TierAllocator`s and
//! competes with tree leaf-frontier nodes for tier bytes under the same
//! replacement policy ([`crate::policy::NodeStats`] + per-tier clocks).
//! A document already cached as a tree node is registered as a
//! [`ChunkSlot::Ref`] instead — the chunk layer shares the node's
//! payload allocation and charges ZERO additional bytes, which is what
//! keeps a doc cached in both structures from being double-charged
//! (the chunk/tree dedupe rule). When a tree insert supersedes an owned
//! entry that is pinned by an in-flight admission, the entry is marked
//! `doomed` and released on its last unpin.
//!
//! Lookup order in the pipeline: prefix walk → chunk probe → miss
//! (see [`crate::tree::KnowledgeTree::chunk_probe`]).

use crate::kvcache::{KvPayload, Tier};
use crate::policy::NodeStats;
use crate::tree::{DocId, NodeId};
use std::collections::BTreeMap;

/// One position-independent chunk-cache hit, recorded in the
/// `Admission` so commit/release can unpin the exact backing entry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChunkHit {
    pub doc: DocId,
    /// Full token span of the cached chunk.
    pub tokens: usize,
    /// First `r` tokens re-prefilled at the new position (charged into
    /// the request's β exactly like uncached tokens).
    pub boundary: usize,
    /// Cached rows reused as-is (`tokens - boundary`), charged into α.
    pub reused_tokens: usize,
    /// Host→GPU bytes this hit streams into the per-batch H2D burst
    /// (zero when the entry is GPU-resident).
    pub h2g_bytes: u64,
    /// The entry backing the hit — pinned at probe time, unpinned at
    /// commit/release through [`ChunkSource`], so a concurrent rebind
    /// of the registry slot can never unbalance the pin ledger.
    pub source: ChunkSource,
}

/// What a chunk hit pinned: a tree node (shared payload) or the owned
/// entry registered under the doc id.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChunkSource {
    /// The doc is cached as this tree node; its request-pin counter was
    /// incremented.
    Node(NodeId),
    /// The doc's owned chunk entry was pinned.
    Owned,
}

/// An owned chunk entry: KV bytes charged against the shared tier
/// allocators, competing with tree leaf-frontier nodes for eviction.
#[derive(Debug)]
pub(crate) struct ChunkEntry {
    pub tokens: usize,
    /// RoPE base offset the KV rows were computed at — the positional
    /// metadata a real engine needs to re-base rotary embeddings when
    /// splicing the chunk at a different position.
    pub rope_offset: usize,
    pub tier: Tier,
    /// In-flight admissions referencing this entry; pinned entries are
    /// never evicted.
    pub pinned: u32,
    /// A tree insert superseded this entry while it was pinned; it is
    /// released on the last unpin instead of double-charging the tiers.
    pub doomed: bool,
    pub stats: NodeStats,
    pub payload: Option<KvPayload>,
}

/// Registry slot for one document.
#[derive(Debug)]
pub(crate) enum ChunkSlot {
    /// Cached as a tree node: reuse its payload, zero extra bytes. May
    /// go stale when the node is dropped from the cache — probes
    /// validate residency before hitting.
    Ref(NodeId),
    /// Owned entry charged against the tier allocators.
    Owned(ChunkEntry),
}

/// The chunk-cache state carried by a [`crate::tree::KnowledgeTree`]
/// when `--chunk-cache on`. Absent entirely when off, so the off path
/// is structurally identical to the tree-only pipeline.
#[derive(Debug)]
pub(crate) struct ChunkState {
    /// `r`: boundary tokens re-prefilled per cross-position reuse.
    pub boundary_tokens: usize,
    pub slots: BTreeMap<DocId, ChunkSlot>,
}

impl ChunkState {
    pub fn new(boundary_tokens: usize) -> Self {
        ChunkState {
            boundary_tokens,
            slots: BTreeMap::new(),
        }
    }
}
