//! Minimal property-based testing framework.
//!
//! `proptest` is unavailable offline, so this provides the subset the test
//! suite needs: run a property over many randomly generated cases from a
//! seeded [`Rng`](crate::util::Rng); on failure, retry with simpler sizes
//! (shrink-lite) and report the failing seed so the case is reproducible.

use crate::util::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig {
            cases: 128,
            seed: 0xC0FFEE,
        }
    }
}

/// Run `prop` over `cfg.cases` random cases. `prop` receives a fresh RNG
/// per case and returns `Err(description)` to signal failure.
///
/// Panics with the case index + seed so a failure is reproducible with
/// `check_with(PropConfig { cases: 1, seed: <reported> }, ..)`.
pub fn check_with<F>(cfg: PropConfig, name: &str, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg
            .seed
            .wrapping_add((case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Run with the default configuration.
pub fn check<F>(name: &str, prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    check_with(PropConfig::default(), name, prop)
}

/// Assert helper returning `Result<(), String>` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check_with(
            PropConfig {
                cases: 50,
                seed: 1,
            },
            "counting",
            |_rng| {
                count += 1;
                Ok(())
            },
        );
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'failing'")]
    fn failing_property_panics_with_seed() {
        check("failing", |rng| {
            let x = rng.below(10);
            prop_assert!(x < 5, "x was {x}");
            Ok(())
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let mut first = Vec::new();
        check_with(
            PropConfig { cases: 10, seed: 7 },
            "collect1",
            |rng| {
                first.push(rng.next_u64());
                Ok(())
            },
        );
        let mut second = Vec::new();
        check_with(
            PropConfig { cases: 10, seed: 7 },
            "collect2",
            |rng| {
                second.push(rng.next_u64());
                Ok(())
            },
        );
        assert_eq!(first, second);
    }
}
