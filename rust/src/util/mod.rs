//! Standard-library-only utility substrates.
//!
//! The offline build environment ships no `rand`, `serde`, or stats crates,
//! so the primitives every other module needs are implemented here from
//! scratch: a PCG PRNG with the distribution samplers the workloads need
//! ([`rng`]), streaming statistics and percentile estimation ([`stats`]),
//! a JSON encoder/decoder ([`json`]), and small collection helpers
//! ([`heap`]).

pub mod rng;
pub mod stats;
pub mod json;
pub mod heap;

pub use rng::Rng;
pub use stats::Summary;

/// Format a byte count as a human-readable string (GiB/MiB/KiB).
pub fn fmt_bytes(bytes: u64) -> String {
    const KIB: f64 = 1024.0;
    let b = bytes as f64;
    if b >= KIB * KIB * KIB {
        format!("{:.2} GiB", b / (KIB * KIB * KIB))
    } else if b >= KIB * KIB {
        format!("{:.2} MiB", b / (KIB * KIB))
    } else if b >= KIB {
        format!("{:.2} KiB", b / KIB)
    } else {
        format!("{} B", bytes)
    }
}

/// Format a duration in seconds adaptively (s / ms / µs).
pub fn fmt_secs(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{:.3} s", secs)
    } else if secs >= 1e-3 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.1} µs", secs * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
        assert_eq!(fmt_bytes(5 * 1024 * 1024 * 1024), "5.00 GiB");
    }

    #[test]
    fn fmt_secs_units() {
        assert_eq!(fmt_secs(1.5), "1.500 s");
        assert_eq!(fmt_secs(0.0225), "22.50 ms");
        assert_eq!(fmt_secs(12e-6), "12.0 µs");
    }
}
