//! Minimal JSON value model, parser and serializer.
//!
//! Replaces `serde_json` (unavailable offline). Covers the full JSON
//! grammar; numbers are f64 (adequate for manifests, bench output and the
//! wire protocol).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= u64::MAX as f64 {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    /// Parse a JSON document.
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

/// JSON parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", lit)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.literal("null", Json::Null),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected '{}'", c as char))),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(out),
                b'\\' => {
                    match self.bump().ok_or_else(|| self.err("bad escape"))? {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // Surrogate pairs.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.err("bad surrogate"));
                                }
                                let c = 0x10000
                                    + ((cp - 0xD800) << 10)
                                    + (lo - 0xDC00);
                                char::from_u32(c)
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(c.ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                b if b < 0x20 => return Err(self.err("control char in string")),
                b => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if b < 0x80 {
                        out.push(b as char);
                    } else {
                        let len = match b {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            0xF0..=0xF7 => 4,
                            _ => return Err(self.err("bad utf-8")),
                        };
                        let start = self.pos - 1;
                        for _ in 1..len {
                            self.bump().ok_or_else(|| self.err("bad utf-8"))?;
                        }
                        let s = std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("bad utf-8"))?;
                        out.push_str(s);
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("bad \\u"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{}", b),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{}", n)
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}", v)?;
                }
                write!(f, "]")
            }
            Json::Obj(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{}", v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{}", c)?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::str("hi"));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": null}"#)
            .unwrap();
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_bool(),
            Some(false)
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"é😀");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"nested":{"k":true},"z":null}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"\\x\"").is_err());
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo 世界");
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn integer_display_has_no_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }
}
