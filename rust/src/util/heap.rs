//! Ordered-float keyed min-heap helpers.
//!
//! `std::collections::BinaryHeap` needs `Ord`, which `f64` lacks; the
//! schedulers and the discrete-event simulator all key on time or priority
//! floats, so this wrapper is used throughout.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// A total-ordered f64 wrapper (NaN is treated as greatest; callers never
/// produce NaN keys in practice, asserted in debug builds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OrdF64(pub f64);

impl Eq for OrdF64 {}

impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> Ordering {
        debug_assert!(!self.0.is_nan() && !other.0.is_nan());
        self.0
            .partial_cmp(&other.0)
            .unwrap_or(Ordering::Equal)
    }
}

/// Min-heap of `(f64 key, T)` entries with FIFO tie-breaking.
///
/// Ties are broken by insertion sequence so that equal-priority items pop
/// in arrival order — required for deterministic simulation replay.
#[derive(Debug, Clone)]
pub struct MinHeap<T> {
    heap: BinaryHeap<Entry<T>>,
    seq: u64,
}

#[derive(Debug, Clone)]
struct Entry<T> {
    key: OrdF64,
    seq: u64,
    item: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key && self.seq == other.seq
    }
}
impl<T> Eq for Entry<T> {}

impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed for min-heap; lower seq wins ties.
        other
            .key
            .cmp(&self.key)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl<T> Default for MinHeap<T> {
    fn default() -> Self {
        MinHeap {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }
}

impl<T> MinHeap<T> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, key: f64, item: T) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry {
            key: OrdF64(key),
            seq,
            item,
        });
    }

    pub fn pop(&mut self) -> Option<(f64, T)> {
        self.heap.pop().map(|e| (e.key.0, e.item))
    }

    pub fn peek_key(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.key.0)
    }

    /// Key and payload of the head without popping — lets layered
    /// schedulers (lazy cancellation) inspect whether the head is live.
    pub fn peek(&self) -> Option<(f64, &T)> {
        self.heap.peek().map(|e| (e.key.0, &e.item))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn clear(&mut self) {
        self.heap.clear();
    }
}

/// Keep the `k` smallest `(f64, T)` pairs seen — a bounded max-heap, the
/// core of top-k candidate tracking in vector search.
#[derive(Debug, Clone)]
pub struct TopK<T> {
    k: usize,
    // Max-heap on key: the root is the current worst of the best-k.
    heap: BinaryHeap<Entry<T>>,
    seq: u64,
}

impl<T: Clone> TopK<T> {
    pub fn new(k: usize) -> Self {
        assert!(k > 0);
        TopK {
            k,
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }

    /// Offer a candidate; returns true if it entered the top-k.
    pub fn offer(&mut self, key: f64, item: T) -> bool {
        let seq = self.seq;
        self.seq += 1;
        if self.heap.len() < self.k {
            self.heap.push(Entry {
                // Negate so the BinaryHeap max = worst (largest key).
                key: OrdF64(-key),
                seq,
                item,
            });
            return true;
        }
        let worst = -self.heap.peek().unwrap().key.0;
        if key < worst {
            self.heap.pop();
            self.heap.push(Entry {
                key: OrdF64(-key),
                seq,
                item,
            });
            true
        } else {
            false
        }
    }

    /// Current worst key among the kept top-k (None if under capacity).
    pub fn threshold(&self) -> Option<f64> {
        if self.heap.len() < self.k {
            None
        } else {
            self.heap.peek().map(|e| -e.key.0)
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Snapshot of the current top-k, best (smallest key) first.
    pub fn sorted(&self) -> Vec<(f64, T)> {
        let mut v: Vec<(f64, T)> = self
            .heap
            .iter()
            .map(|e| (-e.key.0, e.item.clone()))
            .collect();
        v.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minheap_orders_by_key() {
        let mut h = MinHeap::new();
        h.push(3.0, "c");
        h.push(1.0, "a");
        h.push(2.0, "b");
        assert_eq!(h.pop(), Some((1.0, "a")));
        assert_eq!(h.pop(), Some((2.0, "b")));
        assert_eq!(h.pop(), Some((3.0, "c")));
        assert_eq!(h.pop(), None);
    }

    #[test]
    fn minheap_fifo_on_ties() {
        let mut h = MinHeap::new();
        h.push(1.0, "first");
        h.push(1.0, "second");
        h.push(1.0, "third");
        assert_eq!(h.pop().unwrap().1, "first");
        assert_eq!(h.pop().unwrap().1, "second");
        assert_eq!(h.pop().unwrap().1, "third");
    }

    #[test]
    fn topk_keeps_smallest() {
        let mut t = TopK::new(3);
        for (d, id) in [(5.0, 5), (1.0, 1), (4.0, 4), (2.0, 2), (3.0, 3)] {
            t.offer(d, id);
        }
        let got: Vec<i32> = t.sorted().into_iter().map(|(_, x)| x).collect();
        assert_eq!(got, vec![1, 2, 3]);
        assert_eq!(t.threshold(), Some(3.0));
    }

    #[test]
    fn topk_under_capacity_threshold_none() {
        let mut t = TopK::new(4);
        t.offer(1.0, ());
        assert_eq!(t.threshold(), None);
    }
}
