//! Streaming statistics and percentile estimation.
//!
//! Every experiment in the paper reports means and tail latencies; this
//! module provides the summary machinery the metrics layer and the bench
//! harness build on.

/// Accumulates samples and reports mean / percentiles / extrema.
///
/// Stores raw samples (f64) — fine for the sample counts this repo sees
/// (≤ millions); percentile queries sort lazily and cache the sorted order.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Self {
        Summary::default()
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    pub fn extend(&mut self, xs: &[f64]) {
        self.samples.extend_from_slice(xs);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.sum() / self.samples.len() as f64
    }

    /// Sample standard deviation (n-1 denominator).
    pub fn std(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let mean = self.mean();
        let ss: f64 = self.samples.iter().map(|x| (x - mean).powi(2)).sum();
        (ss / (n - 1) as f64).sqrt()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
            self.sorted = true;
        }
    }

    /// Percentile in `[0, 100]` by linear interpolation.
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let n = self.samples.len();
        if n == 1 {
            return self.samples[0];
        }
        let rank = (p / 100.0).clamp(0.0, 1.0) * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.samples[lo] * (1.0 - frac) + self.samples[hi] * frac
    }

    /// Percentile in `[0, 100]` by the nearest-rank method: the smallest
    /// sample whose rank covers `p`% of the distribution (1-based rank
    /// `⌈p/100 · n⌉`). Unlike [`Summary::percentile`], this never
    /// interpolates *below* the tail — p99.9 over fewer than 1000
    /// samples is the maximum, which is what an SLO tail report must
    /// say (the interpolated value would understate the worst case).
    pub fn percentile_nearest(&mut self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let n = self.samples.len();
        let rank = ((p / 100.0).clamp(0.0, 1.0) * n as f64).ceil() as usize;
        self.samples[rank.clamp(1, n) - 1]
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    /// p99.9 tail by nearest rank (SLO reporting; see
    /// [`Summary::percentile_nearest`]).
    pub fn p999(&mut self) -> f64 {
        self.percentile_nearest(99.9)
    }

    /// Smallest sample; NaN when empty, like `mean`/`percentile` — a
    /// bare fold would report `+inf`, which then leaks into JSON bench
    /// reports as a spurious finite-looking extreme.
    pub fn min(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// Largest sample; NaN when empty (see [`Summary::min`]).
    pub fn max(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples
            .iter()
            .cloned()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

/// Empirical CDF over a set of counts — used to reproduce the paper's
/// Fig. 5/6 document-access CDFs ("CDF of requests vs fraction of
/// documents, most-popular first").
pub fn access_cdf(counts: &[u64]) -> Vec<(f64, f64)> {
    let mut sorted: Vec<u64> = counts.iter().cloned().filter(|&c| c > 0).collect();
    sorted.sort_unstable_by(|a, b| b.cmp(a));
    let total: u64 = sorted.iter().sum();
    if total == 0 || sorted.is_empty() {
        return vec![];
    }
    let n = sorted.len();
    let mut acc = 0u64;
    let mut out = Vec::with_capacity(n);
    for (i, c) in sorted.iter().enumerate() {
        acc += c;
        out.push(((i + 1) as f64 / n as f64, acc as f64 / total as f64));
    }
    out
}

/// Interpolate an access CDF at a document-fraction point (e.g. "top 3%").
pub fn cdf_at(cdf: &[(f64, f64)], doc_frac: f64) -> f64 {
    if cdf.is_empty() {
        return 0.0;
    }
    let mut prev = (0.0, 0.0);
    for &(x, y) in cdf {
        if x >= doc_frac {
            let (x0, y0) = prev;
            if x - x0 <= f64::EPSILON {
                return y;
            }
            return y0 + (y - y0) * (doc_frac - x0) / (x - x0);
        }
        prev = (x, y);
    }
    cdf.last().unwrap().1
}

/// A fixed-bucket histogram for latency distributions.
#[derive(Debug, Clone)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
}

impl Histogram {
    /// Exponential buckets from `min` doubling until `max` is covered.
    pub fn exponential(min: f64, max: f64) -> Self {
        assert!(min > 0.0 && max > min);
        let mut bounds = vec![min];
        while *bounds.last().unwrap() < max {
            let next = bounds.last().unwrap() * 2.0;
            bounds.push(next);
        }
        let counts = vec![0; bounds.len() + 1];
        Histogram { bounds, counts }
    }

    pub fn record(&mut self, x: f64) {
        // A NaN sample (e.g. an aggregate over zero requests) lands in
        // the unbounded overflow bucket instead of poisoning the
        // binary search's `partial_cmp(..).unwrap()` — a histogram
        // shared by serving threads must never panic mid-run.
        if x.is_nan() {
            *self.counts.last_mut().expect("counts never empty") += 1;
            return;
        }
        let idx = match self
            .bounds
            .binary_search_by(|b| b.partial_cmp(&x).unwrap())
        {
            Ok(i) => i + 1,
            Err(i) => i,
        };
        self.counts[idx] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// (upper_bound, count) pairs; final bucket is unbounded (`inf`).
    pub fn buckets(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::with_capacity(self.counts.len());
        for (i, &c) in self.counts.iter().enumerate() {
            let ub = if i < self.bounds.len() {
                self.bounds[i]
            } else {
                f64::INFINITY
            };
            out.push((ub, c));
        }
        out
    }
}

/// Bilinear interpolation on an irregular grid, the primitive behind the
/// paper's Algorithm 1 cost estimation `T(alpha, beta)`.
///
/// `xs` and `ys` are strictly increasing axes; `z[i][j]` is the value at
/// `(xs[i], ys[j])`. Queries outside the grid clamp to the border.
#[derive(Debug, Clone)]
pub struct BilinearGrid {
    xs: Vec<f64>,
    ys: Vec<f64>,
    z: Vec<Vec<f64>>,
}

impl BilinearGrid {
    pub fn new(xs: Vec<f64>, ys: Vec<f64>, z: Vec<Vec<f64>>) -> Self {
        assert_eq!(z.len(), xs.len(), "grid rows");
        assert!(z.iter().all(|row| row.len() == ys.len()), "grid cols");
        assert!(xs.windows(2).all(|w| w[0] < w[1]), "xs increasing");
        assert!(ys.windows(2).all(|w| w[0] < w[1]), "ys increasing");
        BilinearGrid { xs, ys, z }
    }

    fn bracket(axis: &[f64], v: f64) -> (usize, usize, f64) {
        if v <= axis[0] {
            return (0, 0, 0.0);
        }
        if v >= *axis.last().unwrap() {
            let last = axis.len() - 1;
            return (last, last, 0.0);
        }
        let hi = axis.partition_point(|&a| a < v);
        let lo = hi - 1;
        let t = (v - axis[lo]) / (axis[hi] - axis[lo]);
        (lo, hi, t)
    }

    /// Interpolated value at `(x, y)` — paper Algorithm 1 lines 6–9.
    pub fn at(&self, x: f64, y: f64) -> f64 {
        let (xi0, xi1, tx) = Self::bracket(&self.xs, x);
        let (yi0, yi1, ty) = Self::bracket(&self.ys, y);
        let z00 = self.z[xi0][yi0];
        let z10 = self.z[xi1][yi0];
        let z01 = self.z[xi0][yi1];
        let z11 = self.z[xi1][yi1];
        let lo = z00 + (z10 - z00) * tx;
        let hi = z01 + (z11 - z01) * tx;
        lo + (hi - lo) * ty
    }

    pub fn xs(&self) -> &[f64] {
        &self.xs
    }

    pub fn ys(&self) -> &[f64] {
        &self.ys
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        s.extend(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.median(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.std() - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn percentile_interpolates() {
        let mut s = Summary::new();
        s.extend(&[0.0, 10.0]);
        assert_eq!(s.percentile(50.0), 5.0);
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(100.0), 10.0);
    }

    /// Satellite: p99.9 must never interpolate below the tail. With
    /// n < 1000 samples the 99.9th percentile IS the maximum under
    /// nearest-rank; the interpolating `percentile` would report less.
    #[test]
    fn p999_nearest_rank_small_samples() {
        // n = 1: the only sample.
        let mut s = Summary::new();
        s.add(7.0);
        assert_eq!(s.p999(), 7.0);

        // n = 10: the max, NOT an interpolation below it.
        let mut s = Summary::new();
        s.extend(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 100.0]);
        assert_eq!(s.p999(), 100.0);
        assert!(
            s.percentile(99.9) < 100.0,
            "interpolating percentile understates the tail — that's \
             why p999 uses nearest rank"
        );

        // n = 1000: rank ⌈0.999·1000⌉ = 999 → the 999th smallest.
        let mut s = Summary::new();
        for i in 1..=1000 {
            s.add(i as f64);
        }
        assert_eq!(s.p999(), 999.0);
        assert_eq!(s.percentile_nearest(100.0), 1000.0);
        assert_eq!(s.percentile_nearest(0.0), 1.0);

        // Empty stays NaN like every other aggregate.
        assert!(Summary::new().p999().is_nan());
    }

    /// Satellite bugfix: an empty sample set must report NaN from every
    /// aggregate — `min`/`max` used to return ±INFINITY, inconsistent
    /// with `percentile` and liable to leak `inf` into JSON reports.
    #[test]
    fn empty_summary_aggregates_are_nan() {
        let mut s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.percentile(50.0).is_nan());
        assert!(s.min().is_nan(), "empty min must be NaN, not +inf");
        assert!(s.max().is_nan(), "empty max must be NaN, not -inf");
        // One sample restores normal behaviour.
        s.add(2.0);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 2.0);
    }

    /// Satellite bugfix: recording NaN must count into the overflow
    /// bucket, not panic a serving thread via `partial_cmp().unwrap()`.
    #[test]
    fn histogram_accepts_nan_into_overflow() {
        let mut h = Histogram::exponential(1.0, 8.0);
        h.record(f64::NAN);
        h.record(2.0);
        h.record(f64::NAN);
        assert_eq!(h.total(), 3);
        let buckets = h.buckets();
        let (ub, count) = *buckets.last().unwrap();
        assert_eq!(ub, f64::INFINITY);
        assert_eq!(count, 2, "both NaNs in the overflow bucket");
    }

    #[test]
    fn cdf_skewed_counts() {
        // 1 hot doc with 60 hits, 9 cold docs with ~4.4 hits each:
        // top 10% of docs should carry 60% of accesses.
        let mut counts = vec![60u64];
        counts.extend(std::iter::repeat(5).take(9));
        let cdf = access_cdf(&counts);
        assert!((cdf_at(&cdf, 0.1) - 60.0 / 105.0).abs() < 1e-9);
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_buckets() {
        let mut h = Histogram::exponential(1.0, 8.0);
        for x in [0.5, 1.5, 3.0, 100.0] {
            h.record(x);
        }
        assert_eq!(h.total(), 4);
        let buckets = h.buckets();
        assert_eq!(buckets[0], (1.0, 1)); // 0.5 ≤ 1.0
        assert_eq!(buckets[1], (2.0, 1)); // 1.5
        assert_eq!(buckets[2], (4.0, 1)); // 3.0
        assert_eq!(*buckets.last().unwrap(), (f64::INFINITY, 1)); // 100.0
    }

    #[test]
    fn bilinear_exact_on_plane() {
        // z = 2x + 3y is reproduced exactly by bilinear interpolation.
        let xs = vec![0.0, 1.0, 4.0];
        let ys = vec![0.0, 2.0, 8.0];
        let z: Vec<Vec<f64>> = xs
            .iter()
            .map(|&x| ys.iter().map(|&y| 2.0 * x + 3.0 * y).collect())
            .collect();
        let g = BilinearGrid::new(xs, ys, z);
        assert!((g.at(0.5, 1.0) - 4.0).abs() < 1e-12);
        assert!((g.at(2.0, 5.0) - 19.0).abs() < 1e-12);
    }

    #[test]
    fn bilinear_clamps_outside() {
        let g = BilinearGrid::new(
            vec![0.0, 1.0],
            vec![0.0, 1.0],
            vec![vec![0.0, 1.0], vec![2.0, 3.0]],
        );
        assert_eq!(g.at(-5.0, -5.0), 0.0);
        assert_eq!(g.at(9.0, 9.0), 3.0);
    }
}
