//! Deterministic PRNG and distribution samplers.
//!
//! PCG-XSH-RR 64/32 (O'Neill 2014) — small, fast, statistically solid, and
//! fully deterministic across platforms, which matters because every
//! workload trace and synthetic embedding in this repo is seeded.

/// PCG-XSH-RR 64/32 pseudo-random number generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
}

impl Rng {
    /// Create a generator from a seed. Distinct seeds give independent
    /// streams in practice; the stream constant is fixed.
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng { state: 0, inc: (seed << 1) | 1 };
        let _ = rng.next_u32();
        rng.state = rng.state.wrapping_add(0x853c_49e6_748f_ea9b ^ seed);
        let _ = rng.next_u32();
        rng
    }

    /// Derive a child generator; useful for giving each sub-component its
    /// own independent stream from one master seed.
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64-bit output (two 32-bit draws).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` (Lemire's method, unbiased).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform in the inclusive range `[lo, hi]`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli draw with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn gaussian(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with the given mean and standard deviation.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gaussian()
    }

    /// Log-normal: `exp(N(mu, sigma))`. Used for document-length sampling
    /// (paper Fig. 3's long-tailed Wikipedia distribution).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Exponential with the given rate (mean `1/rate`). Inter-arrival gaps
    /// of the Poisson arrival process (§7 workloads).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / rate
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.index(i + 1);
            items.swap(i, j);
        }
    }

    /// Sample an index from explicit (unnormalised) weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut target = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Zipf-distributed sampler over `{0, 1, .., n-1}` with exponent `s`,
/// rank 0 most popular. Built once (O(n)), sampled in O(log n) via binary
/// search on the CDF. The paper's retrieval skew (Fig. 5: top 3% of
/// documents take 60% of requests for MMLU) is modelled with this.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0, "zipf over empty domain");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Zipf { cdf }
    }

    pub fn n(&self) -> usize {
        self.cdf.len()
    }

    /// Draw a rank in `[0, n)`.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self.cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    /// Probability mass of rank `k`.
    pub fn pmf(&self, k: usize) -> f64 {
        if k == 0 {
            self.cdf[0]
        } else {
            self.cdf[k] - self.cdf[k - 1]
        }
    }

    /// Fraction of mass held by the top `frac` of ranks — used to calibrate
    /// the exponent against the paper's reported skew.
    pub fn top_mass(&self, frac: f64) -> f64 {
        let k = ((self.cdf.len() as f64 * frac).ceil() as usize)
            .clamp(1, self.cdf.len());
        self.cdf[k - 1]
    }

    /// Find the exponent `s` such that the top `frac` of ranks hold
    /// approximately `mass` of the distribution (bisection).
    pub fn calibrate(n: usize, frac: f64, mass: f64) -> f64 {
        let (mut lo, mut hi) = (0.01, 3.0);
        for _ in 0..40 {
            let mid = 0.5 * (lo + hi);
            if Zipf::new(n, mid).top_mass(frac) < mass {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        0.5 * (lo + hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Rng::new(7);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut rng = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[rng.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "counts: {:?}", counts);
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.gaussian();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {}", mean);
        assert!((var - 1.0).abs() < 0.03, "var {}", var);
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Rng::new(13);
        let rate = 4.0;
        let n = 100_000;
        let mean: f64 =
            (0..n).map(|_| rng.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {}", mean);
    }

    #[test]
    fn zipf_rank0_most_popular() {
        let z = Zipf::new(1000, 1.0);
        let mut rng = Rng::new(5);
        let mut counts = vec![0usize; 1000];
        for _ in 0..100_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[500]);
    }

    #[test]
    fn zipf_calibration_hits_target() {
        // Paper Fig. 5 (MMLU): top 3% of docs ≈ 60% of requests.
        let s = Zipf::calibrate(10_000, 0.03, 0.60);
        let mass = Zipf::new(10_000, s).top_mass(0.03);
        assert!((mass - 0.60).abs() < 0.01, "mass {}", mass);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(17);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut rng = Rng::new(23);
        let weights = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[rng.weighted_index(&weights)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }
}
