//! Fig. 15 — performance with different top-k values (1, 3, 5) on MMLU +
//! Mistral-7B. Documents are truncated harder at higher k (the paper
//! truncates the top-5 setting to fit GPU capacity).

use ragcache::baselines;
use ragcache::bench::{run_sim, Report};
use ragcache::config::SystemConfig;
use ragcache::controller::RetrievalTiming;
use ragcache::util::json::Json;
use ragcache::workload::datasets::MMLU;

const NUM_DOCS: usize = 60_000;
const REQUESTS: usize = 400;

fn main() {
    let mut r = Report::new(
        "fig15_topk",
        "MMLU/Mistral-7B: mean TTFT (s) by top-k and system (rate 0.8)",
        &["top_k", "system", "ttft_s", "hit_rate", "vs_vllm"],
    );
    for top_k in [1usize, 3, 5] {
        let mut base = SystemConfig::default();
        base.retrieval.top_k = top_k;
        let mut vllm_ttft = 0.0;
        let mut rows = Vec::new();
        for (name, cfg) in baselines::all(&base) {
            let out = run_sim(
                &cfg,
                &MMLU,
                NUM_DOCS,
                0.8,
                REQUESTS,
                RetrievalTiming::default(),
                44,
            );
            let ttft = out.recorder.ttft().mean();
            if name == "vllm" {
                vllm_ttft = ttft;
            }
            rows.push((name, ttft, out.recorder.hit_rate()));
        }
        for (name, ttft, hr) in rows {
            r.row(vec![
                Json::num(top_k as f64),
                Json::str(name),
                Json::num(ttft),
                Json::num(hr),
                Json::num(vllm_ttft / ttft),
            ]);
        }
    }
    r.note("paper: RAGCache 1.7-3.1x vs vLLM, 1.2-2.5x vs SGLang across top-k");
    r.note("knowledge tree evicts furthest-from-root first, so hot prefixes survive permutation growth");
    r.finish();
}
