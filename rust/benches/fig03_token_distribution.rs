//! Fig. 3 — token-count distributions: Wikipedia-like documents vs MMLU
//! questions.

use ragcache::bench::Report;
use ragcache::util::json::Json;
use ragcache::util::{Rng, Summary};
use ragcache::workload::{datasets::MMLU, Corpus};

fn main() {
    let corpus = Corpus::wikipedia_like(100_000, 1);
    let mut docs = Summary::new();
    for &t in corpus.all_tokens() {
        docs.add(t as f64);
    }
    let mut questions = Summary::new();
    let mut rng = Rng::new(2);
    for _ in 0..100_000 {
        questions.add(MMLU.sample_request_tokens(&mut rng) as f64);
    }
    let mut r = Report::new(
        "fig03_token_distribution",
        "token counts: documents vs MMLU questions",
        &["series", "p10", "p50", "p90", "p99", "mean"],
    );
    for (name, s) in [("documents", &mut docs), ("mmlu_questions", &mut questions)] {
        let mean = s.mean();
        r.row(vec![
            Json::str(name),
            Json::num(s.percentile(10.0)),
            Json::num(s.percentile(50.0)),
            Json::num(s.percentile(90.0)),
            Json::num(s.percentile(99.0)),
            Json::num(mean),
        ]);
    }
    r.note("paper: average document length 3718 tokens, far above question lengths");
    r.finish();
}
