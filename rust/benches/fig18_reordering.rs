//! Fig. 18 — cache-aware reordering ablation: mean TTFT with/without
//! reordering under saturation (MMLU @ 2.5 req/s, NQ @ 1.4 req/s),
//! host memory 16–128 GiB, window 32.

use ragcache::bench::{run_sim, Report};
use ragcache::config::SystemConfig;
use ragcache::controller::RetrievalTiming;
use ragcache::util::json::Json;
use ragcache::workload::datasets::{MMLU, NATURAL_QUESTIONS};

const NUM_DOCS: usize = 60_000;
const REQUESTS: usize = 500;
const GIB: u64 = 1 << 30;

fn main() {
    let mut r = Report::new(
        "fig18_reordering",
        "cache-aware reordering: mean TTFT (s) slightly above the\n         saturation knee (MMLU 1.35 req/s, NQ 1.1 req/s)",
        &["dataset", "host_gib", "reorder_ttft", "fifo_ttft", "gain"],
    );
    // Regression-bench rows (BENCH_reordering.json): every fig18 run
    // plus chunk-cache-on counterparts at one host size per dataset,
    // with the full metric set ci.sh diffs against bench_baselines/.
    let mut bench = Report::new(
        "BENCH_reordering",
        "reordering bench matrix with chunk-cache ablation rows",
        &[
            "dataset",
            "host_gib",
            "reorder",
            "chunk_cache",
            "ttft_p50",
            "ttft_p99",
            "throughput_rps",
            "gpu_hit_bytes",
            "chunk_hits",
            "chunk_hit_bytes",
            "boundary_recompute_tokens",
            "pcie_h2g_bytes",
            "pcie_g2h_bytes",
        ],
    );
    let mut bench_row =
        |ds: &str, host_gib: u64, reorder: bool, chunk: bool| {
            let mut cfg = SystemConfig::default();
            cfg.cache.host_bytes = host_gib * GIB;
            cfg.sched.reorder = reorder;
            cfg.spec.enabled = false; // isolate reordering
            cfg.cache.chunk_cache = chunk;
            let profile = if ds == "mmlu" { &MMLU } else { &NATURAL_QUESTIONS };
            let rate = if ds == "mmlu" { 1.35 } else { 1.1 };
            let out = run_sim(
                &cfg,
                profile,
                NUM_DOCS,
                rate,
                REQUESTS,
                RetrievalTiming::default(),
                47,
            );
            let mut ttft = out.recorder.ttft();
            bench.row(vec![
                Json::str(ds),
                Json::num(host_gib as f64),
                Json::str(if reorder { "on" } else { "off" }),
                Json::str(if chunk { "on" } else { "off" }),
                Json::num(ttft.median()),
                Json::num(ttft.p99()),
                Json::num(out.recorder.throughput()),
                Json::num(
                    out.tree_counters
                        .map(|c| c.gpu_hit_bytes)
                        .unwrap_or(0) as f64,
                ),
                Json::num(out.chunk_hits() as f64),
                Json::num(out.chunk_hit_bytes() as f64),
                Json::num(out.boundary_recompute_tokens() as f64),
                Json::num(out.pcie_h2g_bytes as f64),
                Json::num(out.pcie_g2h_bytes as f64),
            ]);
            out.recorder.ttft().mean()
        };
    for (ds, _rate) in [("mmlu", 1.35), ("nq", 1.1)] {
        for host_gib in [16u64, 32, 64, 128] {
            let mut ttfts = Vec::new();
            for reorder in [true, false] {
                ttfts.push(bench_row(ds, host_gib, reorder, false));
            }
            r.row(vec![
                Json::str(ds),
                Json::num(host_gib as f64),
                Json::num(ttfts[0]),
                Json::num(ttfts[1]),
                Json::num(ttfts[1] / ttfts[0]),
            ]);
        }
        // Chunk-cache ablation rows at one host size, both orders.
        for reorder in [true, false] {
            bench_row(ds, 32, reorder, true);
        }
    }
    r.note("paper: reordering reduces TTFT by 1.2-2.1x at saturating rates (window 32)");
    r.finish();
    bench.note(
        "ttft/throughput rows are virtual-clock deterministic \
         (seed 47); chunk rows at host_gib=32 only",
    );
    bench.finish();
}
