//! Fig. 18 — cache-aware reordering ablation: mean TTFT with/without
//! reordering under saturation (MMLU @ 2.5 req/s, NQ @ 1.4 req/s),
//! host memory 16–128 GiB, window 32.

use ragcache::bench::{run_sim, Report};
use ragcache::config::SystemConfig;
use ragcache::controller::RetrievalTiming;
use ragcache::util::json::Json;
use ragcache::workload::datasets::{MMLU, NATURAL_QUESTIONS};

const NUM_DOCS: usize = 60_000;
const REQUESTS: usize = 500;
const GIB: u64 = 1 << 30;

fn main() {
    let mut r = Report::new(
        "fig18_reordering",
        "cache-aware reordering: mean TTFT (s) slightly above the\n         saturation knee (MMLU 1.35 req/s, NQ 1.1 req/s)",
        &["dataset", "host_gib", "reorder_ttft", "fifo_ttft", "gain"],
    );
    for (profile, ds, rate) in
        [(&MMLU, "mmlu", 1.35), (&NATURAL_QUESTIONS, "nq", 1.1)]
    {
        for host_gib in [16u64, 32, 64, 128] {
            let mut ttfts = Vec::new();
            for reorder in [true, false] {
                let mut cfg = SystemConfig::default();
                cfg.cache.host_bytes = host_gib * GIB;
                cfg.sched.reorder = reorder;
                cfg.spec.enabled = false; // isolate reordering
                let out = run_sim(
                    &cfg,
                    profile,
                    NUM_DOCS,
                    rate,
                    REQUESTS,
                    RetrievalTiming::default(),
                    47,
                );
                ttfts.push(out.recorder.ttft().mean());
            }
            r.row(vec![
                Json::str(ds),
                Json::num(host_gib as f64),
                Json::num(ttfts[0]),
                Json::num(ttfts[1]),
                Json::num(ttfts[1] / ttfts[0]),
            ]);
        }
    }
    r.note("paper: reordering reduces TTFT by 1.2-2.1x at saturating rates (window 32)");
    r.finish();
}
