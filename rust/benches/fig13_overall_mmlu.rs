//! Fig. 13 — overall performance on MMLU: mean TTFT vs request rate for
//! RAGCache / SGLang / vLLM on Mistral-7B and LLaMA2-7B (A10G testbed),
//! plus the 5×-SLO throughput per system.

use ragcache::baselines;
use ragcache::bench::{run_sim, Report};
use ragcache::config::SystemConfig;
use ragcache::controller::RetrievalTiming;
use ragcache::metrics::slo_throughput;
use ragcache::util::json::Json;
use ragcache::workload::datasets::MMLU;

const NUM_DOCS: usize = 60_000;
const REQUESTS: usize = 400;

fn main() {
    let rates = [0.25, 0.5, 0.75, 1.0, 1.25, 1.5];
    let mut r = Report::new(
        "fig13_overall_mmlu",
        "MMLU: mean TTFT (s) vs request rate, by model and system",
        &["model", "system", "rate", "ttft_s", "hit_rate"],
    );
    let mut tput = Report::new(
        "fig13_throughput_mmlu",
        "MMLU: 5x-SLO throughput (req/s)",
        &["model", "system", "throughput"],
    );
    for model in ["mistral-7b", "llama2-7b"] {
        let mut base = SystemConfig::default();
        base.engine.model = model.to_string();
        for (name, cfg) in baselines::all(&base) {
            let mut points = Vec::new();
            for &rate in &rates {
                let out = run_sim(
                    &cfg,
                    &MMLU,
                    NUM_DOCS,
                    rate,
                    REQUESTS,
                    RetrievalTiming::default(),
                    42,
                );
                let ttft = out.recorder.ttft().mean();
                points.push((rate, ttft));
                r.row(vec![
                    Json::str(model),
                    Json::str(name),
                    Json::num(rate),
                    Json::num(ttft),
                    Json::num(out.recorder.hit_rate()),
                ]);
            }
            tput.row(vec![
                Json::str(model),
                Json::str(name),
                Json::num(slo_throughput(&points, 5.0)),
            ]);
        }
    }
    r.note("paper: RAGCache 1.2-4x lower TTFT than vLLM, 1.1-3.5x than SGLang");
    r.finish();
    tput.note("paper: RAGCache 1.3-2.1x vLLM throughput, 1.2-1.8x SGLang");
    tput.finish();
}
