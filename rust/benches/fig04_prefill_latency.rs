//! Fig. 4 — prefill latency: full computation vs cached prefix vs cached
//! prefix + host→GPU KV transmission (request = 32 tokens).

use ragcache::bench::Report;
use ragcache::kvcache::TransferModel;
use ragcache::llm::models::{A10G, LLAMA2_7B};
use ragcache::llm::CostModel;
use ragcache::util::json::Json;

fn main() {
    let cm = CostModel::new(LLAMA2_7B, A10G);
    let transfer = TransferModel::pcie4();
    let request = 32usize;
    let mut r = Report::new(
        "fig04_prefill_latency",
        "prefill latency: full vs cached prefix vs cached+transfer \
         (LLaMA2-7B, 32-token request)",
        &[
            "prefix_tokens",
            "full_prefill_s",
            "cached_prefix_s",
            "cached_plus_transfer_s",
            "full_over_cached",
            "full_over_hit",
        ],
    );
    for prefix in [128usize, 256, 512, 1024, 2048, 4096] {
        let full = cm.prefill_time(0, prefix + request);
        let cached = cm.prefill_time(prefix, request);
        let kv_bytes = prefix as u64 * cm.model.kv_bytes_per_token as u64;
        let hit = cached + transfer.transfer_time(kv_bytes);
        r.row(vec![
            Json::num(prefix as f64),
            Json::num(full),
            Json::num(cached),
            Json::num(hit),
            Json::num(full / cached),
            Json::num(full / hit),
        ]);
    }
    r.note("paper: cached prefix up to 11.5x faster; with transfer still up to 3.9x");
    r.finish();
}
