//! Fig. 16 — large models on 2×H800: Mixtral-8×7B (batch 8) and
//! LLaMA2-70B (batch 4), four request rates each, with a TTFT SLO of 5×
//! the lowest-rate TTFT.

use ragcache::baselines;
use ragcache::bench::{run_sim, Report};
use ragcache::config::SystemConfig;
use ragcache::controller::RetrievalTiming;
use ragcache::util::json::Json;
use ragcache::workload::datasets::MMLU;

const NUM_DOCS: usize = 60_000;
const REQUESTS: usize = 300;

fn main() {
    let mut r = Report::new(
        "fig16_large_models",
        "large models on 2xH800 (MMLU): mean TTFT (s) vs rate; SLO = 5x \
         TTFT at the lowest rate",
        &["model", "system", "rate", "ttft_s", "meets_slo"],
    );
    for (model, max_batch, rates) in [
        ("mixtral-8x7b", 8usize, [1.0, 1.5, 2.0, 2.5]),
        ("llama2-70b", 4usize, [0.5, 1.0, 1.5, 2.0]),
    ] {
        let mut base = SystemConfig::preset("h800-large").unwrap();
        base.engine.model = model.to_string();
        base.engine.max_batch = max_batch;
        for (name, cfg) in baselines::all(&base) {
            let mut slo = f64::INFINITY;
            for (i, &rate) in rates.iter().enumerate() {
                let out = run_sim(
                    &cfg,
                    &MMLU,
                    NUM_DOCS,
                    rate,
                    REQUESTS,
                    RetrievalTiming::default(),
                    45,
                );
                let ttft = out.recorder.ttft().mean();
                if i == 0 {
                    slo = ttft * 5.0;
                }
                r.row(vec![
                    Json::str(model),
                    Json::str(name),
                    Json::num(rate),
                    Json::num(ttft),
                    Json::Bool(ttft <= slo),
                ]);
            }
        }
    }
    r.note("paper: RAGCache 1.4-2.1x lower TTFT than vLLM at low rates; vLLM misses the SLO above 2 / 1.5 req/s");
    r.finish();
}
