//! Fig. 14 — overall performance on Natural Questions: mean TTFT vs
//! request rate (multi-token outputs, weaker skew than MMLU).

use ragcache::baselines;
use ragcache::bench::{run_sim, Report};
use ragcache::config::SystemConfig;
use ragcache::controller::RetrievalTiming;
use ragcache::metrics::slo_throughput;
use ragcache::util::json::Json;
use ragcache::workload::datasets::NATURAL_QUESTIONS;

const NUM_DOCS: usize = 60_000;
const REQUESTS: usize = 400;

fn main() {
    let rates = [0.2, 0.4, 0.6, 0.8, 1.0, 1.2];
    let mut r = Report::new(
        "fig14_overall_nq",
        "Natural Questions: mean TTFT (s) vs request rate",
        &["model", "system", "rate", "ttft_s", "hit_rate"],
    );
    let mut tput = Report::new(
        "fig14_throughput_nq",
        "Natural Questions: 5x-SLO throughput (req/s)",
        &["model", "system", "throughput"],
    );
    for model in ["mistral-7b", "llama2-7b"] {
        let mut base = SystemConfig::default();
        base.engine.model = model.to_string();
        for (name, cfg) in baselines::all(&base) {
            let mut points = Vec::new();
            for &rate in &rates {
                let out = run_sim(
                    &cfg,
                    &NATURAL_QUESTIONS,
                    NUM_DOCS,
                    rate,
                    REQUESTS,
                    RetrievalTiming::default(),
                    43,
                );
                let ttft = out.recorder.ttft().mean();
                points.push((rate, ttft));
                r.row(vec![
                    Json::str(model),
                    Json::str(name),
                    Json::num(rate),
                    Json::num(ttft),
                    Json::num(out.recorder.hit_rate()),
                ]);
            }
            tput.row(vec![
                Json::str(model),
                Json::str(name),
                Json::num(slo_throughput(&points, 5.0)),
            ]);
        }
    }
    r.note("paper: NQ benefits less than MMLU (weaker skew); SGLang ~ vLLM on NQ");
    r.finish();
    tput.finish();
}
