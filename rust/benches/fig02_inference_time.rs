//! Fig. 2 — LLM inference time vs input length (LLaMA2-7B on A10G),
//! fixed short output. Prefill dominates and crosses ~1 s past 4k tokens.

use ragcache::bench::Report;
use ragcache::llm::models::{A10G, LLAMA2_7B, MISTRAL_7B};
use ragcache::llm::CostModel;
use ragcache::util::json::Json;

fn main() {
    let mut r = Report::new(
        "fig02_inference_time",
        "inference time vs input length (A10G, output = 8 tokens)",
        &["input_tokens", "llama2_7b_s", "mistral_7b_s", "llama_prefill_s"],
    );
    let llama = CostModel::new(LLAMA2_7B, A10G);
    let mistral = CostModel::new(MISTRAL_7B, A10G);
    for len in [128usize, 256, 512, 1024, 2048, 4096, 6144, 8192] {
        let decode =
            |cm: &CostModel| -> f64 {
                (0..8).map(|i| cm.decode_step_time(&[len + i])).sum()
            };
        let l_pre = llama.prefill_time(0, len);
        let l_total = l_pre + decode(&llama);
        let m_total = mistral.prefill_time(0, len) + decode(&mistral);
        r.row(vec![
            Json::num(len as f64),
            Json::num(l_total),
            Json::num(m_total),
            Json::num(l_pre),
        ]);
    }
    r.note("paper: LLaMA2-7B reaches ~1 s past 4000 input tokens; prefill dominates");
    r.finish();
}
