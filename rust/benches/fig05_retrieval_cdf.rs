//! Fig. 5 — CDF of document accesses for the four QA datasets (top-1
//! retrieval): a small fraction of documents serves most requests.

use ragcache::bench::Report;
use ragcache::util::json::Json;
use ragcache::util::stats::{access_cdf, cdf_at};
use ragcache::util::Rng;
use ragcache::workload::datasets::ALL_DATASETS;

fn main() {
    let num_docs = 100_000;
    let samples = 300_000;
    let mut r = Report::new(
        "fig05_retrieval_cdf",
        "document access CDF per dataset (fraction of requests served by \
         top x% of documents)",
        &["dataset", "top_1pct", "top_3pct", "top_10pct", "top_30pct"],
    );
    for &d in ALL_DATASETS {
        let sampler = d.popularity(num_docs);
        let mut rng = Rng::new(11);
        let mut counts = vec![0u64; num_docs];
        for _ in 0..samples {
            counts[sampler.sample(&mut rng) as usize] += 1;
        }
        let cdf = access_cdf(&counts);
        r.row(vec![
            Json::str(d.name),
            Json::num(cdf_at(&cdf, 0.01)),
            Json::num(cdf_at(&cdf, 0.03)),
            Json::num(cdf_at(&cdf, 0.10)),
            Json::num(cdf_at(&cdf, 0.30)),
        ]);
    }
    r.note("paper: MMLU top 3% of documents serve ~60% of requests (20x denser than uniform)");
    r.finish();
}
