//! Fig. 17 + Table 2 — replacement-policy ablation: hit rate and mean
//! TTFT for PGDSF / GDSF / LRU / LFU, host memory 8–128 GiB, MMLU and
//! Natural Questions at 0.8 req/s.

use ragcache::bench::{run_sim, Report};
use ragcache::config::{PolicyKind, SystemConfig};
use ragcache::controller::RetrievalTiming;
use ragcache::util::json::Json;
use ragcache::workload::datasets::{MMLU, NATURAL_QUESTIONS};

const NUM_DOCS: usize = 60_000;
const REQUESTS: usize = 600;
const GIB: u64 = 1 << 30;

fn main() {
    let mut r = Report::new(
        "fig17_policy_ablation",
        "hit rate and mean TTFT by replacement policy and host memory \
         (0.8 req/s)",
        &["dataset", "host_gib", "policy", "hit_rate", "ttft_s"],
    );
    for (profile, ds) in [(&MMLU, "mmlu"), (&NATURAL_QUESTIONS, "nq")] {
        for host_gib in [8u64, 16, 32, 64, 128] {
            for policy in [
                PolicyKind::Pgdsf,
                PolicyKind::Gdsf,
                PolicyKind::Lru,
                PolicyKind::Lfu,
            ] {
                let mut cfg = SystemConfig::default();
                cfg.cache.policy = policy;
                cfg.cache.host_bytes = host_gib * GIB;
                cfg.spec.enabled = false; // isolate the policy effect
                let out = run_sim(
                    &cfg,
                    profile,
                    NUM_DOCS,
                    0.8,
                    REQUESTS,
                    RetrievalTiming::default(),
                    46,
                );
                r.row(vec![
                    Json::str(ds),
                    Json::num(host_gib as f64),
                    Json::str(policy.name()),
                    Json::num(out.recorder.hit_rate()),
                    Json::num(out.recorder.ttft().mean()),
                ]);
            }
        }
    }
    r.note("paper: PGDSF hit rate 1.02-1.32x GDSF, 1.06-1.62x LRU, 1.06-1.75x LFU; TTFT 1.05-1.29x lower (Table 2)");
    r.finish();
}
