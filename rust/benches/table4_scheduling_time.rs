//! Table 4 — controller scheduling time (knowledge-tree lookup/update,
//! reordering, DSP decisions) vs request rate. Measured as real
//! wall-clock time of the decision code inside the simulation, plus
//! microbenchmarks of the individual operations.

use ragcache::bench::{run_sim, time_for, Report};
use ragcache::config::{PolicyKind, SystemConfig};
use ragcache::controller::RetrievalTiming;
use ragcache::kvcache::PageSpec;
use ragcache::policy::{make_policy, AccessCtx};
use ragcache::tree::KnowledgeTree;
use ragcache::util::json::Json;
use ragcache::workload::datasets::MMLU;

const NUM_DOCS: usize = 60_000;

fn main() {
    let mut r = Report::new(
        "table4_scheduling_time",
        "controller scheduling time per decision (MMLU, Mistral-7B)",
        &["request_rate", "sched_time_us"],
    );
    for rate in [0.5f64, 1.0, 1.5, 2.0] {
        let cfg = SystemConfig::default();
        let out = run_sim(
            &cfg,
            &MMLU,
            NUM_DOCS,
            rate,
            400,
            RetrievalTiming::default(),
            49,
        );
        r.row(vec![
            Json::num(rate),
            Json::num(out.mean_sched_time * 1e6),
        ]);
    }
    r.note("paper Table 4: 0.87-0.91 ms end-to-end scheduling per request; ours is per decision");
    r.finish();

    // Microbenchmarks of the constituent operations on a populated tree.
    let mut micro = Report::new(
        "table4_micro",
        "knowledge-tree operation microbenchmarks",
        &["operation", "mean_us", "p99_us"],
    );
    let page = PageSpec {
        block_tokens: 16,
        kv_bytes_per_token: 131072,
    };
    let mut tree = KnowledgeTree::new(
        200 * (1u64 << 30),
        400 * (1u64 << 30),
        page,
        make_policy(PolicyKind::Pgdsf),
        true,
        0,
    );
    // Populate with 2000 two-doc paths.
    for d in 0..2000u32 {
        let a = tree
            .insert_child(tree.root(), d, 1900, None)
            .1
            .expect("fits");
        tree.insert_child(a, 100_000 + d, 1900, None);
    }
    let mut i = 0u32;
    let mut lookup = time_for(0.2, || {
        i = (i + 1) % 2000;
        std::hint::black_box(tree.lookup(&[i, 100_000 + i]));
    });
    micro.row(vec![
        Json::str("tree_lookup"),
        Json::num(lookup.mean() * 1e6),
        Json::num(lookup.p99() * 1e6),
    ]);
    let ctx = AccessCtx {
        alpha: 1900,
        beta: 2000,
        estimated_time: 0.5,
        was_cached: true,
        now: 1.0,
        tokens: 1900,
    };
    let path = tree.lookup(&[5, 100_005]).path;
    let mut update = time_for(0.2, || {
        for &n in &path {
            tree.on_access(n, &ctx);
        }
    });
    micro.row(vec![
        Json::str("policy_update_path"),
        Json::num(update.mean() * 1e6),
        Json::num(update.p99() * 1e6),
    ]);
    micro.note("all operations are far below the paper's 1 ms budget");
    micro.finish();
}
