//! Fig. 19 + Table 3 — dynamic speculative pipelining ablation: TTFT and
//! non-overlapping vector-search time vs the searched-vector ratio
//! (12.5%–100% of the database), 0.1 req/s.
//!
//! The full (100%) search is calibrated to the paper's Table 3 No-DSP
//! column (~422 ms MMLU / ~446 ms NQ); smaller ratios scale linearly.

use ragcache::bench::{run_sim, Report};
use ragcache::config::SystemConfig;
use ragcache::controller::RetrievalTiming;
use ragcache::util::json::Json;
use ragcache::workload::datasets::{MMLU, NATURAL_QUESTIONS};

const NUM_DOCS: usize = 60_000;
const REQUESTS: usize = 300;

fn main() {
    let mut fig = Report::new(
        "fig19_speculative",
        "DSP ablation: mean TTFT (s) vs vector-search ratio (0.1 req/s)",
        &["dataset", "search_ratio", "dsp_ttft", "nodsp_ttft", "gain"],
    );
    let mut table3 = Report::new(
        "table3_nonoverlap_search",
        "average non-overlapping vector-search time (ms)",
        &["dataset", "search_ratio", "dsp_ms", "nodsp_ms", "reduction"],
    );
    for (profile, ds, full_s) in [
        (&MMLU, "mmlu", 0.4223),
        (&NATURAL_QUESTIONS, "nq", 0.4461),
    ] {
        for ratio in [0.125f64, 0.25, 0.5, 1.0] {
            let timing = RetrievalTiming {
                full_search_s: full_s * ratio,
                stages: 4,
                // Lower ratios search fewer vectors => the top-k emerges
                // relatively later in the (shorter) search.
                early_convergence: 0.45 + 0.15 * ratio,
            };
            let mut ttfts = Vec::new();
            let mut overlaps = Vec::new();
            for dsp in [true, false] {
                let mut cfg = SystemConfig::default();
                cfg.spec.enabled = dsp;
                cfg.sched.reorder = false;
                let out = run_sim(
                    &cfg, profile, NUM_DOCS, 0.1, REQUESTS, timing, 48,
                );
                ttfts.push(out.recorder.ttft().mean());
                overlaps
                    .push(out.recorder.mean_non_overlapped_search() * 1e3);
            }
            fig.row(vec![
                Json::str(ds),
                Json::num(ratio),
                Json::num(ttfts[0]),
                Json::num(ttfts[1]),
                Json::num(ttfts[1] / ttfts[0]),
            ]);
            table3.row(vec![
                Json::str(ds),
                Json::num(ratio),
                Json::num(overlaps[0]),
                Json::num(overlaps[1]),
                Json::num(overlaps[1] / overlaps[0]),
            ]);
        }
    }
    fig.note("paper: up to 1.6x TTFT reduction with DSP");
    fig.finish();
    table3.note("paper Table 3: non-overlapping search time 1.5-4.3x lower with DSP");
    table3.finish();
}
