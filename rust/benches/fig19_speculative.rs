//! Fig. 19 + Table 3 — dynamic speculative pipelining ablation: TTFT and
//! non-overlapping vector-search time vs the searched-vector ratio
//! (12.5%–100% of the database), 0.1 req/s.
//!
//! The full (100%) search is calibrated to the paper's Table 3 No-DSP
//! column (~422 ms MMLU / ~446 ms NQ); smaller ratios scale linearly.
//!
//! A third report drives the REAL serving path's session API
//! (`SessionTable` + `RetrievalService`, the `--speculate on`
//! machinery) wall-clock against a synthetic prefill, reporting the
//! same DSP-on/off TTFT comparison the simulator models.

use ragcache::bench::{run_sim, Report};
use ragcache::config::{PolicyKind, SystemConfig};
use ragcache::controller::{
    Admission, FinishPath, RetrievalConfig, RetrievalService,
    RetrievalTask, RetrievalTiming, SessionTable, ShardedCacheService,
};
use ragcache::embed::EmbeddingModel;
use ragcache::kvcache::PageSpec;
use ragcache::policy::make_policy;
use ragcache::tree::KnowledgeTree;
use ragcache::util::json::Json;
use ragcache::vectordb::{FlatIndex, VectorIndex};
use ragcache::workload::datasets::{MMLU, NATURAL_QUESTIONS};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

const NUM_DOCS: usize = 60_000;
const REQUESTS: usize = 300;

/// Session-API wall-clock ablation: serve `n` cold requests through the
/// real lifecycle (staged search on the retrieval pool + pin-only
/// speculative admissions) vs the blocking retrieve-then-prefill shape.
/// Returns (dsp_ttft_s, nodsp_ttft_s) means.
fn session_api_ttft(
    n: usize,
    search: Duration,
    prefill: Duration,
) -> (f64, f64) {
    let corpus = 64usize;
    let em = EmbeddingModel::new(16, 0x519);
    let vecs: Vec<Vec<f32>> =
        (0..corpus as u32).map(|d| em.document(d)).collect();
    let index: Arc<dyn VectorIndex> =
        Arc::new(FlatIndex::build(16, &vecs));
    let page = PageSpec {
        block_tokens: 8,
        kv_bytes_per_token: 16,
    };
    let mk_cache = || {
        ShardedCacheService::build(1, |_| {
            KnowledgeTree::new(
                page.bytes(4096),
                page.bytes(8192),
                page,
                make_policy(PolicyKind::Pgdsf),
                true,
                0,
            )
        })
    };
    // Targets in the first scan quarter converge at stage 1 of 4.
    let target = |i: usize| (i % (corpus / 4)) as u32;

    // Blocking shape: full search, then prefill.
    let svc = mk_cache();
    let mut nodsp = 0.0;
    for i in 0..n {
        let t0 = Instant::now();
        std::thread::sleep(search);
        let docs: Vec<u32> = index
            .search(&em.document(target(i)), 1)
            .iter()
            .map(|h| h.1)
            .collect();
        let adm = svc.admit(&[(docs[0], 16)], 4);
        std::thread::sleep(prefill);
        nodsp += t0.elapsed().as_secs_f64();
        svc.commit(&adm, 1e-3, 1.0, None);
    }

    // Session lifecycle: prefill overlaps stages 2..4 of the search.
    let svc = mk_cache();
    let (tx, rx) = mpsc::channel();
    let service = RetrievalService::spawn(
        Arc::clone(&index),
        RetrievalConfig {
            threads: 2,
            stages: 4,
            stage_latency: search / 4,
        },
        tx,
    );
    let mut table: SessionTable<Admission> = SessionTable::new(4);
    let mut dsp = 0.0;
    for i in 0..n {
        let id = i as u64;
        let t0 = Instant::now();
        table.submit(id, 0.0);
        assert!(service.submit(RetrievalTask {
            session: id,
            query: em.document(target(i)),
            top_k: 1,
            stages: None,
        }));
        loop {
            let ev = rx
                .recv_timeout(Duration::from_secs(10))
                .expect("stage event");
            let step =
                table.on_stage(ev.session, ev.stage, &ev.docs, ev.is_final);
            if let Some(work) = step.cancelled {
                svc.release(&work.payload);
            }
            if let Some(docs) = step.start {
                let adm = svc.admit(&[(docs[0], 16)], 4);
                std::thread::sleep(prefill);
                table.spec_started(id, docs, adm);
            }
            if let Some(finish) = step.finish {
                let adm = match finish {
                    FinishPath::Promote(work) => work.payload,
                    FinishPath::Fallback => {
                        let adm = svc.admit(&[(ev.docs[0], 16)], 4);
                        std::thread::sleep(prefill);
                        adm
                    }
                };
                dsp += t0.elapsed().as_secs_f64();
                table.prefilled(id, 0.0);
                table.decoding(id);
                svc.commit(&adm, 1e-3, 1.0, None);
                table.complete(id);
                table.take_events();
                break;
            }
            table.take_events();
        }
    }
    drop(service);
    (dsp / n as f64, nodsp / n as f64)
}

fn main() {
    let mut fig = Report::new(
        "fig19_speculative",
        "DSP ablation: mean TTFT (s) vs vector-search ratio (0.1 req/s)",
        &["dataset", "search_ratio", "dsp_ttft", "nodsp_ttft", "gain"],
    );
    let mut table3 = Report::new(
        "table3_nonoverlap_search",
        "average non-overlapping vector-search time (ms)",
        &["dataset", "search_ratio", "dsp_ms", "nodsp_ms", "reduction"],
    );
    for (profile, ds, full_s) in [
        (&MMLU, "mmlu", 0.4223),
        (&NATURAL_QUESTIONS, "nq", 0.4461),
    ] {
        for ratio in [0.125f64, 0.25, 0.5, 1.0] {
            let timing = RetrievalTiming {
                full_search_s: full_s * ratio,
                stages: 4,
                // Lower ratios search fewer vectors => the top-k emerges
                // relatively later in the (shorter) search.
                early_convergence: 0.45 + 0.15 * ratio,
            };
            let mut ttfts = Vec::new();
            let mut overlaps = Vec::new();
            for dsp in [true, false] {
                let mut cfg = SystemConfig::default();
                cfg.spec.enabled = dsp;
                cfg.sched.reorder = false;
                let out = run_sim(
                    &cfg, profile, NUM_DOCS, 0.1, REQUESTS, timing, 48,
                );
                ttfts.push(out.recorder.ttft().mean());
                overlaps
                    .push(out.recorder.mean_non_overlapped_search() * 1e3);
            }
            fig.row(vec![
                Json::str(ds),
                Json::num(ratio),
                Json::num(ttfts[0]),
                Json::num(ttfts[1]),
                Json::num(ttfts[1] / ttfts[0]),
            ]);
            table3.row(vec![
                Json::str(ds),
                Json::num(ratio),
                Json::num(overlaps[0]),
                Json::num(overlaps[1]),
                Json::num(overlaps[1] / overlaps[0]),
            ]);
        }
    }
    fig.note("paper: up to 1.6x TTFT reduction with DSP");
    fig.finish();
    table3.note("paper Table 3: non-overlapping search time 1.5-4.3x lower with DSP");
    table3.finish();

    // The real path's session API, wall clock: the same ablation shape
    // through SessionTable + RetrievalService (what `serve --speculate
    // on` runs), swept over search:prefill ratios.
    let mut live = Report::new(
        "fig19_session_api",
        "session-API wall-clock TTFT (s): DSP vs blocking, synthetic \
         prefill",
        &["search_ms", "prefill_ms", "dsp_ttft", "nodsp_ttft", "gain"],
    );
    for (search_ms, prefill_ms) in [(40u64, 10u64), (80, 30)] {
        let (dsp, nodsp) = session_api_ttft(
            6,
            Duration::from_millis(search_ms),
            Duration::from_millis(prefill_ms),
        );
        live.row(vec![
            Json::num(search_ms as f64),
            Json::num(prefill_ms as f64),
            Json::num(dsp),
            Json::num(nodsp),
            Json::num(nodsp / dsp),
        ]);
    }
    live.note(
        "staged search >= prefill: speculation hides the prefill \
         behind the search tail",
    );
    live.finish();
}
