//! Fig. 6 — the retrieval-pattern skew is robust across (a) embedding
//! models and (b) ANN index types: real top-1 searches through the Rust
//! vector indexes, counting which documents the searches actually return.

use ragcache::bench::Report;
use ragcache::embed::EmbeddingModel;
use ragcache::util::json::Json;
use ragcache::util::stats::{access_cdf, cdf_at};
use ragcache::util::Rng;
use ragcache::vectordb::{FlatIndex, HnswIndex, IvfIndex, VectorIndex};
use ragcache::workload::datasets::MMLU;

const NUM_DOCS: usize = 8_000;
const QUERIES: usize = 20_000;
const DIM: usize = 24;

fn measure(index: &dyn VectorIndex, em: &EmbeddingModel, seed: u64) -> Vec<f64> {
    let sampler = MMLU.popularity(NUM_DOCS);
    let mut rng = Rng::new(seed);
    let mut counts = vec![0u64; NUM_DOCS];
    for _ in 0..QUERIES {
        let target = sampler.sample(&mut rng);
        let q = em.query(target, 0.05, &mut rng);
        if let Some(&(_, hit)) = index.search(&q, 1).first() {
            counts[hit as usize] += 1;
        }
    }
    let cdf = access_cdf(&counts);
    vec![
        cdf_at(&cdf, 0.01),
        cdf_at(&cdf, 0.03),
        cdf_at(&cdf, 0.10),
    ]
}

fn main() {
    let mut r = Report::new(
        "fig06_retrieval_settings",
        "access CDF under different embedding models and ANN indexes \
         (MMLU profile, real top-1 searches)",
        &["setting", "top_1pct", "top_3pct", "top_10pct"],
    );

    // (a) Embedding-model sweep: three embedding geometries, Flat index.
    for (name, seed) in [("embed-A", 7u64), ("embed-B", 21), ("embed-C", 63)]
    {
        let em = EmbeddingModel::new(DIM, seed);
        let vecs: Vec<Vec<f32>> =
            (0..NUM_DOCS as u32).map(|d| em.document(d)).collect();
        let flat = FlatIndex::build(DIM, &vecs);
        let c = measure(&flat, &em, 1);
        r.row(vec![
            Json::str(format!("{name}/flat")),
            Json::num(c[0]),
            Json::num(c[1]),
            Json::num(c[2]),
        ]);
    }

    // (b) ANN-index sweep: same embedding, three index types.
    let em = EmbeddingModel::new(DIM, 7);
    let vecs: Vec<Vec<f32>> =
        (0..NUM_DOCS as u32).map(|d| em.document(d)).collect();
    let indexes: Vec<(&str, Box<dyn VectorIndex>)> = vec![
        ("flatl2", Box::new(FlatIndex::build(DIM, &vecs))),
        ("ivf", Box::new(IvfIndex::build(DIM, &vecs, 64, 8, 3))),
        ("hnsw", Box::new(HnswIndex::build(DIM, &vecs, 12, 48, 5))),
    ];
    for (name, idx) in &indexes {
        let c = measure(idx.as_ref(), &em, 2);
        r.row(vec![
            Json::str(format!("embed-A/{name}")),
            Json::num(c[0]),
            Json::num(c[1]),
            Json::num(c[2]),
        ]);
    }
    r.note("paper: the skew is a property of the question distribution — all settings show it");
    r.finish();
}
