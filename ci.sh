#!/usr/bin/env bash
# CI entry point: tier-1 verify + hygiene gates + the e2e example.
#
#   ./ci.sh          run everything available in the toolchain
#
# The build environment is fully offline; all dependencies are vendored
# path crates (see vendor/README.md), so no network is required.
set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check
else
    echo "warn: rustfmt unavailable, skipping format gate"
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy -D warnings =="
    cargo clippy --all-targets -- -D warnings
else
    echo "warn: clippy unavailable, skipping lint gate"
fi

# The PJRT-backed e2e example needs AOT artifacts (make artifacts, which
# requires the Python/JAX toolchain). It exits non-zero on any serving
# regression, so run it whenever the artifacts exist.
if [ -f artifacts/manifest.json ]; then
    echo "== e2e serving example =="
    cargo run --release --example e2e_serving
else
    echo "warn: artifacts/ not built, skipping e2e serving example"
fi

echo "CI OK"
