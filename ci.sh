#!/usr/bin/env bash
# CI entry point: tier-1 verify + hygiene gates + the e2e example.
#
#   ./ci.sh          run everything available in the toolchain
#
# The build environment is fully offline; all dependencies are vendored
# path crates (see vendor/README.md), so no network is required.
set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check
else
    echo "warn: rustfmt unavailable, skipping format gate"
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy -D warnings =="
    cargo clippy --all-targets -- -D warnings
else
    echo "warn: clippy unavailable, skipping lint gate"
fi

# Concurrent serving matrix (PJRT-free): the multi-worker/multi-engine
# TCP runtime over the sharded cache with a synthetic engine. Runs
# everywhere; exits non-zero on any regression, keeping the concurrent
# paths exercised even without artifacts.
echo "== concurrent serving matrix (PJRT-free) =="
for w in 1 4; do
    for e in 1 2; do
        echo "-- serving_matrix --workers $w --engines $e --"
        cargo run --release --example serving_matrix -- \
            --workers "$w" --engines "$e"
    done
done

# The PJRT-backed e2e example needs AOT artifacts (make artifacts, which
# requires the Python/JAX toolchain). It exits non-zero on any serving
# regression, so run it whenever the artifacts exist — first the direct
# composition proof, then the real-compute TCP matrix.
if [ -f artifacts/manifest.json ]; then
    echo "== e2e serving example =="
    cargo run --release --example e2e_serving
    for w in 1 4; do
        for e in 1 2; do
            echo "-- e2e_serving --workers $w --engines $e --"
            cargo run --release --example e2e_serving -- \
                --workers "$w" --engines "$e"
        done
    done
else
    echo "warn: artifacts/ not built, skipping e2e serving example"
fi

echo "CI OK"
