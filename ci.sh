#!/usr/bin/env bash
# CI entry point: tier-1 verify + hygiene gates + the e2e example.
#
#   ./ci.sh          run everything available in the toolchain
#
# The build environment is fully offline; all dependencies are vendored
# path crates (see vendor/README.md), so no network is required.
set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

# Stats-schema drift gate: the metric registry's generated schema (wire
# names, merge kinds, tolerance classes, bench columns) must match the
# committed snapshot exactly — a stat silently added or removed fails
# here, mirroring the bench_diff column-set rule. Regenerate with:
#   cargo run --release --bin ragcache -- stats-schema \
#     > bench_baselines/stats_schema.txt
echo "== stats-schema drift gate =="
mkdir -p bench_out
cargo run --release --bin ragcache -- stats-schema \
    > bench_out/stats_schema.txt
diff -u bench_baselines/stats_schema.txt bench_out/stats_schema.txt

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check
else
    echo "warn: rustfmt unavailable, skipping format gate"
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy -D warnings =="
    cargo clippy --all-targets -- -D warnings
else
    echo "warn: clippy unavailable, skipping lint gate"
fi

# The batched-admission property suite is timing-sensitive (randomized
# multi-thread interleavings at two engines): run it under --release too
# so the fast schedules are exercised, not only the debug ones.
echo "== batched admission suite (--release) =="
cargo test --release --test batched_admission -q

# The session-lifecycle suite drives the event-driven serving API
# (staged retrieval + speculative prefill) with real wall-clock
# overlap, so it too wants --release schedules. staged_search pins the
# retrieval invariants speculation relies on.
echo "== session lifecycle + staged search suites (--release) =="
cargo test --release --test session_lifecycle -q
cargo test --release --test staged_search -q

# Concurrent serving matrix (PJRT-free): the multi-worker/multi-engine
# TCP runtime over the sharded cache with a synthetic engine, swept
# across batched/unbatched admission AND blocking/event-driven serving
# (--speculate off|on). Runs everywhere; exits non-zero on any
# regression, keeping the concurrent paths exercised even without
# artifacts.
echo "== concurrent serving matrix (PJRT-free) =="
for w in 1 4; do
    for e in 1 2; do
        for b in 1 8; do
            for s in off on; do
                echo "-- serving_matrix --workers $w --engines $e --max-batch $b --speculate $s --"
                cargo run --release --example serving_matrix -- \
                    --workers "$w" --engines "$e" --max-batch "$b" \
                    --speculate "$s"
            done
        done
    done
done

# Chunk-level position-independent KV reuse: the randomized property /
# conformance / interleaving suite under --release, then the serving
# matrix swept across --chunk-cache {off,on} on both the batched and
# event-driven paths (off must stay bit-identical to the chunk-free
# path; on must pass the same structural gates with hits accounted).
echo "== chunk reuse suite (--release) =="
cargo test --release --test chunk_reuse -q
echo "== chunk-cache serving sweep =="
for c in off on; do
    for s in off on; do
        echo "-- serving_matrix --workers 4 --engines 2 --speculate $s --chunk-cache $c --"
        cargo run --release --example serving_matrix -- \
            --workers 4 --engines 2 --speculate "$s" --chunk-cache "$c"
    done
done

# Chunk-reuse gate: on a reordered Zipfian doc-pair stream the chunk
# cache must strictly reduce both the summed prefill tokens and the
# TTFT proxy (PCIe + recompute time) vs chunk-off, and must not lose
# on the in-order stream.
echo "== chunk-cache reuse comparison =="
cargo run --release --example serving_matrix -- --compare-chunk-cache

# NVMe disk tier: the conformance / round-trip / interleaving suite
# under --release (the randomized three-tier hammering wants fast
# schedules), then the functional matrix swept across
# --disk {off,on} x --cag {off,auto} (off/off must stay bit-identical
# to the two-tier path; cag auto requires the chunk cache and serves
# the pre-staged corpus without tree inserts).
echo "== disk tier suite (--release) =="
cargo test --release --test disk_tier -q
echo "== disk/CAG serving sweep =="
for d in off on; do
    for g in off auto; do
        cc=off
        if [ "$g" = auto ]; then cc=on; fi
        echo "-- serving_matrix --workers 4 --engines 2 --disk $d --cag $g --chunk-cache $cc --"
        cargo run --release --example serving_matrix -- \
            --workers 4 --engines 2 --disk "$d" --cag "$g" \
            --chunk-cache "$cc"
    done
done

# Disk-tier gate: on a Zipfian stream that thrashes the host tier,
# disk-on must strictly reduce the recompute+transfer TTFT proxy with
# restage hits > 0; on a stream that fits in GPU+host it must not lose.
echo "== disk-tier TTFT comparison =="
cargo run --release --example serving_matrix -- --compare-disk

# CAG corpus-pinning gate (discrete-event sim): under a pin budget
# sized to the smaller tenant's corpus, exactly one tenant pins, every
# one of its requests completes with zero retrieval stages, and its
# mean TTFT strictly beats the same tenant served as cached-RAG.
echo "== CAG corpus-pinning comparison =="
cargo run --release --example serving_matrix -- --compare-cag

# Regression benches: emit BENCH_serving (wall-clock serving bench) and
# BENCH_reordering (virtual-clock fig18 matrix + chunk ablation), then
# diff both against the committed bench_baselines/ within per-column
# tolerance bands (provisional baselines pass on schema only).
echo "== regression benches vs baselines =="
cargo run --release --example serving_matrix -- --bench-serving
cargo run --release --example bench_diff -- --name BENCH_serving
cargo bench --bench fig18_reordering
cargo run --release --example bench_diff -- --name BENCH_reordering

# Cross-shard tier rebalancing sweep: the functional matrix under
# --rebalance {off,on} (off must stay bit-identical to the static
# split; on must conserve the configured budget exactly), plus the
# randomized rebalancer suite under --release.
echo "== cross-shard rebalancing sweep =="
for r in off on; do
    echo "-- serving_matrix --workers 4 --engines 2 --shards 4 --rebalance $r --"
    cargo run --release --example serving_matrix -- \
        --workers 4 --engines 2 --shards 4 --rebalance "$r"
done
cargo test --release --test shard_rebalance -q

# Discrete-event simulator core: the conformance suite (shed off must
# stay bit-identical to the iteration-driven predecessor, replicated
# in-test from public APIs) plus the overload acceptance tests.
echo "== event-driven simulator suite (--release) =="
cargo test --release --test event_sim -q

# Real-path admission control: the shed-off conformance / deterministic
# shed / wire-merge suite under --release (wall-clock waits and staged
# retrieval pacing want fast schedules), then the functional matrix
# swept across --shed {off,on} on both serving shapes (off must stay
# bit-identical to the ladder-free path; on must report live SLO stats
# with nothing shed at the generous default SLO).
echo "== real-path admission control suite (--release) =="
cargo test --release --test real_shed -q
echo "== admission-control serving sweep =="
for sh in off on; do
    for s in off on; do
        echo "-- serving_matrix --workers 4 --engines 2 --speculate $s --shed $sh --"
        cargo run --release --example serving_matrix -- \
            --workers 4 --engines 2 --speculate "$s" --shed "$sh"
    done
done

# Open-loop CLI sweep: every arrival process x tenancy x shedding mode
# through the real `simulate` entry point, on a small corpus so the
# sweep stays fast. Exercises flag parsing, trace generation, the SLO
# report and the per-tenant breakdown end to end.
echo "== open-loop simulate sweep =="
for a in poisson bursty diurnal; do
    for t in 1 4; do
        for s in off on; do
            echo "-- simulate --arrivals $a --tenants $t --shed $s --"
            cargo run --release --bin ragcache -- simulate \
                --system ragcache --dataset mmlu --rate 2.0 \
                --requests 60 --docs 2000 --ttft-slo 2.0 \
                --arrivals "$a" --tenants "$t" --shed "$s"
        done
    done
done

# Overload admission-control gate: at ~2x+ the sustainable rate,
# shed-on must strictly win goodput-under-SLO over shed-off, improve
# the served-request p50 TTFT, and account for every request exactly
# once, with per-tenant stats summing to the aggregate.
echo "== overload shedding gate =="
cargo run --release --example overload_gate

# Real-path overload gate: the same closed-loop fleet against a
# retrieval-stalled TCP server with the ladder off and on; shed-on
# must strictly win requests completed within the TTFT SLO, with
# exact completed + shed == submitted accounting on both the client
# and stats sides.
echo "== real-path overload shedding gate =="
cargo run --release --example serving_matrix -- --compare-shed

# Skewed-workload gate: on a Zipfian workload routed to one hot shard,
# rebalance-on must strictly win aggregate GPU cache-hit bytes vs the
# static 1/K split, and must not lose on the uniform workload.
echo "== rebalancing hit-bytes comparison =="
cargo run --release --example serving_matrix -- --compare-rebalance

# Acceptance comparison (retrieval-heavy, cold cache): speculation must
# strictly lower the summed TTFT vs the blocking path.
echo "== speculation TTFT comparison =="
cargo run --release --example serving_matrix -- --compare-speculation

# The PJRT-backed e2e example needs AOT artifacts (make artifacts, which
# requires the Python/JAX toolchain). It exits non-zero on any serving
# regression, so run it whenever the artifacts exist — first the direct
# composition proof, then the real-compute TCP matrix.
if [ -f artifacts/manifest.json ]; then
    echo "== e2e serving example =="
    cargo run --release --example e2e_serving
    for w in 1 4; do
        for e in 1 2; do
            for b in 1 8; do
                echo "-- e2e_serving --workers $w --engines $e --max-batch $b --"
                cargo run --release --example e2e_serving -- \
                    --workers "$w" --engines "$e" --max-batch "$b"
            done
        done
    done
    # Real-PJRT event-driven serving: sessions + speculative prefills.
    echo "-- e2e_serving --workers 4 --engines 2 --speculate on --"
    cargo run --release --example e2e_serving -- \
        --workers 4 --engines 2 --speculate on
    # Chunk-cache sweep on the real-compute matrix: position-independent
    # KV reuse must serve the same workload correctly with real PJRT
    # prefills (off is covered by the sweep above).
    echo "-- e2e_serving --workers 4 --engines 2 --chunk-cache on --"
    cargo run --release --example e2e_serving -- \
        --workers 4 --engines 2 --chunk-cache on
    echo "-- e2e_serving --workers 4 --engines 2 --speculate on --chunk-cache on --"
    cargo run --release --example e2e_serving -- \
        --workers 4 --engines 2 --speculate on --chunk-cache on
else
    echo "warn: artifacts/ not built, skipping e2e serving example"
fi

echo "CI OK"
