# Build entry points. The one everything references is `make artifacts`:
# AOT-compile the tiny PJRT models (L1 Pallas kernel → L2 JAX
# transformer → HLO text + flat params + manifest.json under artifacts/)
# via python/compile/aot.py. Python runs only here, at build time — the
# Rust binary is self-contained afterwards. `ragcache serve`, the
# e2e_serving example and rust/tests/runtime_pjrt.rs all skip or error
# with "run `make artifacts`" until this target has been run; it needs a
# Python environment with jax + numpy (the AOT toolchain), which the
# offline Rust build deliberately does not.

PYTHON ?= python3
OUT    ?= artifacts

.PHONY: artifacts test pytest ci clean-artifacts

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../$(OUT)

# Tier-1 verify (same gate as ci.sh's first two steps).
test:
	cargo build --release
	cargo test -q

# The Python-side contract tests (skip cleanly without artifacts/jax).
pytest:
	cd python && $(PYTHON) -m pytest tests -q

# Full gate: build, tests, lints, serving matrices.
ci:
	./ci.sh

clean-artifacts:
	rm -rf $(OUT)
