//! Replacement-policy ablation (live version of paper §7.3 / Fig. 17):
//! run the same MMLU workload under PGDSF, GDSF, LRU and LFU and compare
//! hit rate and TTFT at several host-memory sizes.
//!
//! Run: `cargo run --release --example policy_ablation`

use ragcache::config::{PolicyKind, SystemConfig};
use ragcache::controller::{RetrievalTiming, SimServer};
use ragcache::workload::{datasets::MMLU, Corpus, Trace};

fn main() -> anyhow::Result<()> {
    let num_docs = 50_000;
    let corpus = Corpus::wikipedia_like(num_docs, 1);
    let trace = Trace::generate(&MMLU, &corpus, 0.8, 600, 2, 21);
    const GIB: u64 = 1 << 30;

    println!(
        "{:<10} {:>10} {:>12} {:>12}",
        "policy", "host(GiB)", "hit-rate", "ttft(s)"
    );
    for host_gib in [16u64, 64] {
        for policy in [
            PolicyKind::Pgdsf,
            PolicyKind::Gdsf,
            PolicyKind::Lru,
            PolicyKind::Lfu,
        ] {
            let mut cfg = SystemConfig::default();
            cfg.cache.policy = policy;
            cfg.cache.host_bytes = host_gib * GIB;
            let server = SimServer::build(
                &cfg,
                trace.clone(),
                num_docs,
                RetrievalTiming::default(),
                3,
            )?;
            let out = server.run();
            println!(
                "{:<10} {:>10} {:>11.1}% {:>12.3}",
                policy.name(),
                host_gib,
                out.recorder.hit_rate() * 100.0,
                out.recorder.ttft().mean(),
            );
        }
        println!();
    }
    println!(
        "PGDSF's bilinear-interpolated per-token cost (Algorithm 1) keeps \
         the most expensive-to-recompute prefixes resident — the paper \
         reports 1.02-1.32x hit-rate gains over GDSF and up to 1.75x \
         over LFU."
    );
    Ok(())
}
