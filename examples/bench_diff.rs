//! Regression-bench differ: compare a freshly emitted
//! `bench_out/<name>.json` report against the committed baseline in
//! `bench_baselines/<name>.json`, with per-column tolerance bands.
//!
//! Column classes:
//! - string columns (row labels) must match exactly, row by row;
//! - columns the metric registry knows get the tolerance class they
//!   were registered with: `Loose` (`--loose-tol`, default 0.75
//!   relative) for wall-clock measurements of the host, `Tight`
//!   (`--tol`, default 0.15 relative) for deterministic counters;
//! - columns outside the registry (bench-local workload shape,
//!   wall-clock percentiles) fall back to the suffix rule: names
//!   ending `_ms` or `_rps` are loose, everything else numeric is
//!   tight — virtual-clock latencies, token sums and byte counters
//!   are deterministic at fixed seed, so drift there is a real
//!   behaviour change.
//!
//! Column sets must match EXACTLY in both directions: a column the
//! fresh report dropped is a regression, and a column the baseline has
//! never heard of is an emitter change that silently escapes the diff
//! — both are explicit failures, never skipped.
//!
//! A baseline whose top level carries `"provisional": true` has not
//! been pinned on real hardware yet: the differ validates that the
//! fresh report parses and matches the baseline's column set, prints
//! how to pin it, and passes. Exits non-zero on any band violation.
//!
//! Run: `cargo run --release --example bench_diff -- --name BENCH_serving`

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};
use ragcache::cli::Args;
use ragcache::util::json::Json;

/// One loaded report: rows as column→value maps, plus the baseline's
/// provisional marker.
struct Bench {
    rows: Vec<BTreeMap<String, Json>>,
    provisional: bool,
}

fn load(path: &str) -> Result<Bench> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {path}"))?;
    let v = Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
    let rows = v
        .get("rows")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("{path}: missing rows"))?
        .iter()
        .map(|r| match r {
            Json::Obj(kvs) => Ok(kvs.clone()),
            _ => bail!("{path}: row is not an object"),
        })
        .collect::<Result<Vec<_>>>()?;
    let provisional = v
        .get("provisional")
        .and_then(Json::as_bool)
        .unwrap_or(false);
    Ok(Bench { rows, provisional })
}

/// Whether a column takes the loose (wall-clock) band. Registered
/// metrics carry their tolerance class in the registry; bench-local
/// columns fall back to the wall-clock naming rule.
fn is_loose(col: &str) -> bool {
    use ragcache::metrics::registry::{tolerance_of, Registry, Tolerance};
    match tolerance_of(&Registry::standard(), col) {
        Some(t) => t == Tolerance::Loose,
        None => col.ends_with("_ms") || col.ends_with("_rps"),
    }
}

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[]).map_err(anyhow::Error::msg)?;
    let name = args
        .get("name")
        .ok_or_else(|| anyhow!("--name <report> is required"))?;
    let tol: f64 =
        args.get_parse_or("tol", 0.15).map_err(anyhow::Error::msg)?;
    let loose_tol: f64 = args
        .get_parse_or("loose-tol", 0.75)
        .map_err(anyhow::Error::msg)?;
    let out_path = format!(
        "{}/{name}.json",
        args.get_or("out-dir", "bench_out")
    );
    let base_path = format!(
        "{}/{name}.json",
        args.get_or("baseline-dir", "bench_baselines")
    );

    let fresh = load(&out_path)?;
    let base = load(&base_path)?;
    if fresh.rows.is_empty() {
        bail!("{out_path}: no rows emitted");
    }

    // Column-set equality, both directions, before any value diffing:
    // a missing column is a dropped measurement, an unknown column is
    // an emitter change the baseline has never vetted — both must be
    // explicit failures, not silently skipped cells.
    let cols_of = |rows: &[BTreeMap<String, Json>]| {
        rows.iter()
            .flat_map(|r| r.keys().cloned())
            .collect::<std::collections::BTreeSet<String>>()
    };
    let fresh_cols = cols_of(&fresh.rows);
    let base_cols = cols_of(&base.rows);
    let missing: Vec<&String> =
        base_cols.difference(&fresh_cols).collect();
    let unknown: Vec<&String> =
        fresh_cols.difference(&base_cols).collect();
    if !missing.is_empty() || !unknown.is_empty() {
        bail!(
            "{name}: column sets differ — fresh report is missing \
             {missing:?}, baseline has never seen {unknown:?} (update \
             bench_baselines/{name}.json deliberately)"
        );
    }

    if base.provisional {
        // Numbers are still unpinned: the column-set equality above is
        // the whole schema check; value diffing waits for a pin.
        println!(
            "bench_diff {name}: baseline is provisional — schema OK, \
             numeric diff skipped.\nPin it with: cp {out_path} \
             {base_path}  (and drop the \"provisional\" flag)"
        );
        return Ok(());
    }

    if fresh.rows.len() != base.rows.len() {
        bail!(
            "{name}: {} rows emitted vs {} in baseline",
            fresh.rows.len(),
            base.rows.len()
        );
    }
    let mut failures = Vec::new();
    for (i, (frow, brow)) in
        fresh.rows.iter().zip(&base.rows).enumerate()
    {
        for col in frow.keys() {
            if !brow.contains_key(col) {
                failures.push(format!(
                    "row {i}: unknown column {col} not in baseline row"
                ));
            }
        }
        for (col, bval) in brow {
            let Some(fval) = frow.get(col) else {
                failures.push(format!("row {i}: missing column {col}"));
                continue;
            };
            match (bval, fval) {
                (Json::Str(b), Json::Str(f)) => {
                    if b != f {
                        failures.push(format!(
                            "row {i} {col}: '{f}' != baseline '{b}'"
                        ));
                    }
                }
                (Json::Num(b), Json::Num(f)) => {
                    let t = if is_loose(col) { loose_tol } else { tol };
                    let band = t * b.abs().max(1e-9);
                    if (f - b).abs() > band {
                        failures.push(format!(
                            "row {i} {col}: {f} outside {b} ± {band:.4} \
                             ({:.0}% band)",
                            t * 100.0
                        ));
                    }
                }
                _ => failures.push(format!(
                    "row {i} {col}: type mismatch vs baseline"
                )),
            }
        }
    }
    if !failures.is_empty() {
        for f in &failures {
            eprintln!("BENCH REGRESSION [{name}]: {f}");
        }
        std::process::exit(1);
    }
    println!(
        "bench_diff {name}: {} rows within tolerance ({}%/{}% bands)",
        base.rows.len(),
        tol * 100.0,
        loose_tol * 100.0
    );
    Ok(())
}
