//! Quickstart: simulate RAGCache vs the vLLM baseline on a small MMLU
//! workload and print the headline comparison.
//!
//! Run: `cargo run --release --example quickstart`

use ragcache::baselines;
use ragcache::config::SystemConfig;
use ragcache::controller::{RetrievalTiming, SimServer};
use ragcache::workload::{datasets::MMLU, Corpus, Trace};

fn main() -> anyhow::Result<()> {
    let num_docs = 50_000;
    let corpus = Corpus::wikipedia_like(num_docs, 1);
    println!(
        "corpus: {} documents, mean {:.0} tokens (Wikipedia-like, Fig. 3)",
        corpus.len(),
        corpus.mean_tokens()
    );
    let base = SystemConfig::default();
    let trace = Trace::generate(&MMLU, &corpus, 1.0, 400, 2, 42);
    println!(
        "workload: {} MMLU-profile requests at {} req/s, top-{}\n",
        trace.requests.len(),
        trace.rate,
        base.retrieval.top_k
    );

    println!(
        "{:<10} {:>10} {:>10} {:>10} {:>10}",
        "system", "ttft(s)", "p99(s)", "hit-rate", "tput(r/s)"
    );
    for (name, cfg) in baselines::all(&base) {
        let server = SimServer::build(
            &cfg,
            trace.clone(),
            num_docs,
            RetrievalTiming::default(),
            7,
        )?;
        let out = server.run();
        let mut ttft = out.recorder.ttft();
        println!(
            "{:<10} {:>10.3} {:>10.3} {:>9.1}% {:>10.2}",
            name,
            ttft.mean(),
            ttft.p99(),
            out.recorder.hit_rate() * 100.0,
            out.recorder.throughput(),
        );
    }
    println!(
        "\nRAGCache caches retrieved-document KV in a GPU/host knowledge \
         tree (PGDSF), reorders cache-aware, and overlaps retrieval with \
         speculative prefill — see examples/e2e_serving.rs for the real \
         PJRT-backed stack."
    );
    Ok(())
}
