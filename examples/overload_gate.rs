//! Overload admission-control gate.
//!
//! Drives the open-loop discrete-event simulator at roughly 2x+ the
//! sustainable rate, with and without the shed/downgrade ladder, and
//! enforces the acceptance bar of the open-loop refactor:
//!
//! - `--shed off` under overload: queues build, nothing deadlocks,
//!   every request eventually completes — but the TTFT tail blows far
//!   past the SLO (the run really was overloaded);
//! - `--shed on`: strictly higher goodput-under-SLO than off, every
//!   request accounted for exactly once (completed or shed), the p50
//!   TTFT of the requests actually served strictly better than the
//!   unshedded run's, and per-tenant stats summing exactly to the
//!   aggregate.
//!
//! Exits non-zero on any violation. Knobs:
//!   --rate R       overload arrival rate, req/s   (default 50)
//!   --requests N   trace length                   (default 120)
//!   --tenants T    tenant count                   (default 4)
//!   --docs D       corpus size                    (default 2000)

use ragcache::config::{SystemConfig, SystemKind, SystemKindField};
use ragcache::controller::{RetrievalTiming, SimOutcome, SimServer};
use ragcache::workload::{
    datasets::MMLU, Corpus, Trace, TraceOptions,
};

fn fail(msg: &str) -> ! {
    eprintln!("FAIL: {msg}");
    std::process::exit(1);
}

fn run(cfg: &SystemConfig, trace: Trace, docs: usize) -> SimOutcome {
    SimServer::build(cfg, trace, docs, RetrievalTiming::default(), 5)
        .expect("sim build")
        .run()
}

fn main() -> anyhow::Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args =
        ragcache::cli::Args::parse(&raw, &[]).map_err(anyhow::Error::msg)?;
    let rate: f64 = args.get_parse_or("rate", 50.0).map_err(anyhow::Error::msg)?;
    let n: usize =
        args.get_parse_or("requests", 120).map_err(anyhow::Error::msg)?;
    let tenants: usize =
        args.get_parse_or("tenants", 4).map_err(anyhow::Error::msg)?;
    let docs: usize =
        args.get_parse_or("docs", 2_000).map_err(anyhow::Error::msg)?;

    let mut cfg = SystemConfig::default();
    cfg.kind = SystemKindField(SystemKind::parse("ragcache")?);
    cfg.cache.gpu_bytes = 8 * (1 << 30);
    cfg.cache.host_bytes = 192 * (1 << 30);

    let corpus = Corpus::wikipedia_like(docs, 1);

    // Calibrate the SLO from an uncongested closed-feasible trickle:
    // 3x its mean TTFT (with a floor so the gate stays meaningful on
    // very fast hosts — the virtual clock makes this deterministic).
    let base = run(
        &cfg,
        Trace::generate(&MMLU, &corpus, 0.3, 40, 2, 11),
        docs,
    );
    let slo = (3.0 * base.recorder.ttft().mean()).max(0.2);
    cfg.shed.ttft_slo_s = slo;

    let mk = || {
        Trace::generate_open_loop(
            &MMLU,
            &corpus,
            rate,
            n,
            &TraceOptions {
                tenants,
                ..TraceOptions::default()
            },
            11,
        )
    };
    let off = run(&cfg, mk(), docs);
    cfg.shed.enabled = true;
    let on = run(&cfg, mk(), docs);

    // Shed off: open loop terminates with everything served, late.
    if off.completed != n || off.shed_requests != 0 {
        fail(&format!(
            "shed off must complete all {n} requests (got {} completed, \
             {} shed)",
            off.completed, off.shed_requests
        ));
    }
    let mut off_ttft = off.recorder.ttft();
    if off_ttft.p999() <= slo {
        fail(&format!(
            "offered rate {rate} req/s did not overload: p99.9 TTFT \
             {:.3}s <= SLO {slo:.3}s — raise --rate",
            off_ttft.p999()
        ));
    }

    // Shed on: exact accounting, strict goodput win.
    if on.shed_requests == 0 {
        fail("shed on under overload must shed at least one request");
    }
    if on.completed + on.shed_requests != n {
        fail(&format!(
            "accounting: {} completed + {} shed != {n}",
            on.completed, on.shed_requests
        ));
    }
    let (g_on, g_off) =
        (on.recorder.goodput(slo), off.recorder.goodput(slo));
    if g_on <= g_off {
        fail(&format!(
            "shed on goodput {g_on:.3} req/s !> off {g_off:.3} req/s"
        ));
    }
    let mut on_ttft = on.recorder.ttft();
    let (p50_on, p50_off) = (on_ttft.median(), off_ttft.median());
    if p50_on >= p50_off {
        fail(&format!(
            "served-request p50 TTFT must improve under shedding: \
             {p50_on:.3}s !< {p50_off:.3}s"
        ));
    }

    let per = on.recorder.per_tenant(slo);
    if per.len() != tenants {
        fail(&format!("{} tenants reported, expected {tenants}", per.len()));
    }
    let sums = (
        per.iter().map(|t| t.requests).sum::<usize>(),
        per.iter().map(|t| t.completed).sum::<usize>(),
        per.iter().map(|t| t.shed).sum::<usize>(),
    );
    if sums != (n, on.completed, on.shed_requests) {
        fail(&format!(
            "per-tenant sums {sums:?} != aggregate ({n}, {}, {})",
            on.completed, on.shed_requests
        ));
    }

    println!(
        "overload gate OK: rate {rate} req/s, SLO {slo:.3}s | off: \
         goodput {g_off:.3} req/s, p50 TTFT {p50_off:.3}s | on: goodput \
         {g_on:.3} req/s, p50 TTFT {p50_on:.3}s, {} shed, {} downgraded",
        on.shed_requests, on.downgraded_requests
    );
    Ok(())
}
