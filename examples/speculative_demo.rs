//! Dynamic speculative pipelining walkthrough (paper §5.3, Fig. 11) plus
//! a small sweep showing the TTFT effect as vector-search latency grows.
//!
//! Run: `cargo run --release --example speculative_demo`

use ragcache::config::SystemConfig;
use ragcache::controller::{RetrievalTiming, SimServer};
use ragcache::spec::{SpecAction, SpecState};
use ragcache::workload::{datasets::MMLU, Corpus, Trace};

fn main() -> anyhow::Result<()> {
    // --- Part 1: the Fig. 11 walkthrough on the state machine itself.
    println!("== Algorithm 2 walkthrough (paper Fig. 11) ==");
    let mut s = SpecState::new();
    let stages: [(&[u32], bool); 4] = [
        (&[1, 3], false), // stage 1: candidates [D1, D3]
        (&[1, 2], false), // stage 2: [D1, D2] — restart
        (&[1, 2], false), // stage 3: unchanged — keep
        (&[1, 2], true),  // final: matches — deliver speculation
    ];
    for (i, (docs, is_final)) in stages.iter().enumerate() {
        let action = s.on_stage(docs, 0, 4, *is_final);
        let desc = match action {
            SpecAction::Start { terminate_prev: false } => {
                "start speculative generation"
            }
            SpecAction::Start { terminate_prev: true } => {
                "terminate stale speculation, start new one"
            }
            SpecAction::Keep => "candidates unchanged — keep running",
            SpecAction::Defer { .. } => "defer (pool full)",
        };
        println!("  stage {} {:?}: {}", i + 1, docs, desc);
    }
    println!(
        "  => {} generations started, {} wasted\n",
        s.started, s.wasted
    );

    // --- Part 2: TTFT vs search latency, DSP on/off (Fig. 19's shape).
    println!("== TTFT vs vector-search latency (rate 0.1 req/s) ==");
    println!(
        "{:>12} {:>12} {:>12} {:>8}",
        "search(ms)", "DSP ttft(s)", "noDSP ttft(s)", "gain"
    );
    let num_docs = 20_000;
    let corpus = Corpus::wikipedia_like(num_docs, 2);
    let trace = Trace::generate(&MMLU, &corpus, 0.1, 150, 2, 5);
    for search_ms in [50.0, 150.0, 400.0, 800.0] {
        let timing = RetrievalTiming {
            full_search_s: search_ms / 1e3,
            stages: 4,
            early_convergence: 0.55,
        };
        let mut ttfts = Vec::new();
        for spec_on in [true, false] {
            let mut cfg = SystemConfig::default();
            cfg.spec.enabled = spec_on;
            let server = SimServer::build(
                &cfg,
                trace.clone(),
                num_docs,
                timing,
                9,
            )?;
            let out = server.run();
            ttfts.push(out.recorder.ttft().mean());
        }
        println!(
            "{:>12.0} {:>12.3} {:>12.3} {:>7.2}x",
            search_ms,
            ttfts[0],
            ttfts[1],
            ttfts[1] / ttfts[0]
        );
    }
    println!(
        "\nSpeculative prefill hides the search tail behind the GPU — the \
         paper reports up to 1.6x TTFT reduction at high search ratios."
    );
    Ok(())
}
