//! End-to-end serving on the REAL three-layer stack:
//!
//!   L1 Pallas prefix-attention kernel → L2 JAX transformer → AOT HLO →
//!   L3 Rust: vector retrieval + knowledge tree + PJRT execution.
//!
//! Loads the tiny GQA model compiled by `make artifacts`, builds a small
//! knowledge corpus with real embeddings, then serves batches of queries
//! — cold and warm — reporting TTFT, throughput and cache hit rate. This
//! is the proof that all layers compose with Python nowhere on the
//! request path.
//!
//! Run: `make artifacts && cargo run --release --example e2e_serving`

use ragcache::controller::real::{RealConfig, RealServer};
use ragcache::embed::EmbeddingModel;
use ragcache::runtime::{ArtifactManifest, PjrtModel};
use ragcache::util::{Rng, Summary};
use ragcache::vectordb::{FlatIndex, VectorIndex};
use ragcache::workload::Corpus;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let manifest = ArtifactManifest::load(dir)?;
    let mm = manifest.model("tiny-gqa")?;
    println!(
        "loading {} ({} buckets, {} params) via PJRT...",
        mm.name,
        mm.buckets.len(),
        mm.param_specs.len()
    );
    let model = PjrtModel::load(mm)?;
    println!("platform: {}", model.platform_name());

    // Knowledge base: 128 short documents with real embeddings + index.
    let num_docs = 128usize;
    let corpus = Corpus::tiny(num_docs, 3);
    let mut rng = Rng::new(9);
    let doc_tokens: Vec<Vec<i32>> = (0..num_docs)
        .map(|d| {
            (0..corpus.tokens(d as u32))
                .map(|_| rng.index(256) as i32)
                .collect()
        })
        .collect();
    let dim = 16;
    let em = EmbeddingModel::new(dim, 17);
    let vecs: Vec<Vec<f32>> =
        (0..num_docs as u32).map(|d| em.document(d)).collect();
    let index: Box<dyn VectorIndex> = Box::new(FlatIndex::build(dim, &vecs));

    let cfg = RealConfig::default();
    let mut server = RealServer::new(model, index, em, doc_tokens, &cfg)?;

    // Skewed query stream: a few hot topics, like the paper's Fig. 5.
    let hot_docs: Vec<u32> = (0..8).collect();
    let mut workload = Vec::new();
    for i in 0..48u32 {
        let target = if i % 4 == 0 {
            8 + (i / 4) % 24 // cold tail
        } else {
            hot_docs[(i as usize) % hot_docs.len()] // hot set
        };
        workload.push(target);
    }

    println!("\nserving {} requests (cold + warm)...", workload.len());
    let mut cold = Summary::new();
    let mut warm = Summary::new();
    let t0 = std::time::Instant::now();
    for (i, &target) in workload.iter().enumerate() {
        let query: Vec<i32> =
            (0..24).map(|_| rng.index(256) as i32).collect();
        let resp = server.serve(target, &query, 4, &cfg)?;
        if resp.docs_hit == 0 {
            cold.add(resp.ttft);
        } else {
            warm.add(resp.ttft);
        }
        if i < 4 || i % 16 == 0 {
            println!(
                "  req {:>2}: docs {:?} hit {}/{} cached {:>3} tokens, \
                 ttft {:>7.1} ms",
                i,
                resp.docs,
                resp.docs_hit,
                resp.docs.len(),
                resp.cached_tokens,
                resp.ttft * 1e3
            );
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    let r = server.recorder();
    let mut ttft = r.ttft();
    let n = r.len();
    let hit_rate = r.hit_rate();
    let token_hit = r.token_hit_rate();
    println!("\n== e2e results (real PJRT compute) ==");
    println!("requests           : {}", n);
    println!("throughput         : {:.2} req/s", n as f64 / wall);
    println!(
        "TTFT mean/p50/p99  : {:.1} / {:.1} / {:.1} ms",
        ttft.mean() * 1e3,
        ttft.median() * 1e3,
        ttft.p99() * 1e3
    );
    println!(
        "cold-miss TTFT     : {:.1} ms over {} requests",
        cold.mean() * 1e3,
        cold.len()
    );
    println!(
        "cache-hit TTFT     : {:.1} ms over {} requests",
        warm.mean() * 1e3,
        warm.len()
    );
    println!("doc hit rate       : {:.1}%", hit_rate * 100.0);
    println!("token hit rate     : {:.1}%", token_hit * 100.0);
    let c = server.cache().counters();
    println!(
        "tree               : {} inserts, {} gpu evictions, {} host \
         evictions",
        c.inserts, c.gpu_evictions, c.host_evictions
    );
    if warm.len() > 0 && cold.len() > 0 {
        println!(
            "\ncaching speedup    : {:.2}x (hit vs miss TTFT)",
            cold.mean() / warm.mean()
        );
    }

    // CI gate: regressions must make the example exit non-zero, not just
    // print odd numbers.
    let mut failures = Vec::new();
    if n != workload.len() {
        failures.push(format!(
            "served {n} of {} requests",
            workload.len()
        ));
    }
    if warm.len() == 0 {
        failures.push("no request ever hit the cache".to_string());
    }
    if hit_rate <= 0.0 {
        failures.push(format!("doc hit rate {hit_rate} not positive"));
    }
    if c.inserts == 0 {
        failures.push("nothing was inserted into the tree".to_string());
    }
    server.cache().check_invariants();
    if !failures.is_empty() {
        for f in &failures {
            eprintln!("FAIL: {f}");
        }
        std::process::exit(1);
    }
    println!("\nOK");
    Ok(())
}
