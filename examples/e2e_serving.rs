//! End-to-end serving on the REAL three-layer stack:
//!
//!   L1 Pallas prefix-attention kernel → L2 JAX transformer → AOT HLO →
//!   L3 Rust: vector retrieval + knowledge tree + PJRT execution.
//!
//! Loads the tiny GQA model compiled by `make artifacts`, builds a small
//! knowledge corpus with real embeddings, then serves batches of queries
//! — cold and warm — reporting TTFT, throughput and cache hit rate. This
//! is the proof that all layers compose with Python nowhere on the
//! request path.
//!
//! Two modes:
//! - default: drive `RealServer::serve` directly (single engine, no
//!   TCP), as the original composition proof.
//! - `--workers N [--engines M] [--max-batch B] [--chunk-cache on]
//!   [--boundary-tokens R]`: run the same workload
//!   through the concurrent TCP runtime — N connection workers, M
//!   engine-driver replicas sharing one M-shard knowledge-tree cache,
//!   each admitting up to B requests per iteration with their cache-hit
//!   transfers coalesced into one burst — exercising shard-affinity
//!   routing, batched admission and cross-engine stats fan-out with
//!   real PJRT compute. This is the CI matrix entry point.
//!
//! Run: `make artifacts && cargo run --release --example e2e_serving`
//!      `... --example e2e_serving -- --workers 4 --engines 2`

use ragcache::cli::Args;
use ragcache::controller::real::{
    RealConfig, RealServer, SessionProtoBridge,
};
use ragcache::embed::EmbeddingModel;
use ragcache::llm::ByteTokenizer;
use ragcache::runtime::{ArtifactManifest, PjrtModel};
use ragcache::server::{
    proto, Client, PriorityEstimator, QueryHandler, Server,
    ServerOptions, ShardFn,
};
use ragcache::util::{Rng, Summary};
use ragcache::vectordb::{FlatIndex, VectorIndex};
use ragcache::workload::Corpus;
use std::path::Path;
use std::sync::Arc;

const NUM_DOCS: usize = 128;

/// The deterministic knowledge base both modes (and every engine
/// replica) build: token ids, embeddings, vector index.
fn build_corpus(
) -> (Vec<Vec<i32>>, EmbeddingModel, Box<dyn VectorIndex>) {
    let corpus = Corpus::tiny(NUM_DOCS, 3);
    let mut rng = Rng::new(9);
    let doc_tokens: Vec<Vec<i32>> = (0..NUM_DOCS)
        .map(|d| {
            (0..corpus.tokens(d as u32))
                .map(|_| rng.index(256) as i32)
                .collect()
        })
        .collect();
    let dim = 16;
    let em = EmbeddingModel::new(dim, 17);
    let vecs: Vec<Vec<f32>> =
        (0..NUM_DOCS as u32).map(|d| em.document(d)).collect();
    let index: Box<dyn VectorIndex> = Box::new(FlatIndex::build(dim, &vecs));
    (doc_tokens, em, index)
}

/// Skewed query stream: a few hot topics, like the paper's Fig. 5.
fn skewed_workload() -> Vec<u32> {
    let hot_docs: Vec<u32> = (0..8).collect();
    let mut workload = Vec::new();
    for i in 0..48u32 {
        let target = if i % 4 == 0 {
            8 + (i / 4) % 24 // cold tail
        } else {
            hot_docs[(i as usize) % hot_docs.len()] // hot set
        };
        workload.push(target);
    }
    workload
}

fn main() -> anyhow::Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[]).map_err(anyhow::Error::msg)?;
    let workers: usize = args
        .get_parse_or("workers", 0)
        .map_err(anyhow::Error::msg)?;
    let engines: usize = args
        .get_parse_or("engines", 1)
        .map_err(anyhow::Error::msg)?;
    let max_batch: usize = args
        .get_parse_or("max-batch", ServerOptions::default().max_batch)
        .map_err(anyhow::Error::msg)?;
    if max_batch == 0 {
        anyhow::bail!("--max-batch must be >= 1");
    }
    let speculate = match args.get_or("speculate", "off") {
        "on" => true,
        "off" => false,
        other => anyhow::bail!("--speculate expects on|off, got {other}"),
    };
    let chunk_cache = match args.get_or("chunk-cache", "off") {
        "on" => true,
        "off" => false,
        other => {
            anyhow::bail!("--chunk-cache expects on|off, got {other}")
        }
    };
    let boundary_tokens: usize = args
        .get_parse_or("boundary-tokens", 8)
        .map_err(anyhow::Error::msg)?;
    if chunk_cache && boundary_tokens == 0 {
        anyhow::bail!(
            "--boundary-tokens must be >= 1 with --chunk-cache on"
        );
    }

    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    if workers > 0 {
        return serve_tcp_matrix(
            dir,
            workers,
            engines.max(1),
            max_batch,
            speculate,
            chunk_cache,
            boundary_tokens,
        );
    }
    serve_direct(dir)
}

/// PJRT-backed handler for the TCP mode (each engine replica owns one).
/// Session plumbing and stats delegate to the library's
/// [`SessionProtoBridge`] / `RealServer::proto_stats` — the same code
/// the `ragcache serve` binary's handler runs.
struct TcpHandler {
    server: RealServer,
    cfg: RealConfig,
    tok: ByteTokenizer,
    bridge: SessionProtoBridge,
}

impl QueryHandler for TcpHandler {
    fn query(
        &mut self,
        target_doc: u32,
        query: &str,
        max_new: usize,
    ) -> anyhow::Result<proto::QueryResult> {
        self.query_batch(&[(target_doc, query.to_string(), max_new)])
            .pop()
            .expect("one result per query")
    }

    /// Batched entry point with real PJRT compute: members admit
    /// together (one coalesced H2D accounting burst), then prefill and
    /// decode in turn — the identical `serve_proto_batch` path the
    /// `ragcache serve` binary runs.
    fn query_batch(
        &mut self,
        batch: &[(u32, String, usize)],
    ) -> Vec<anyhow::Result<proto::QueryResult>> {
        self.server.serve_proto_batch(batch, &self.tok, &self.cfg)
    }

    /// [`query_batch`](QueryHandler::query_batch) plus per-member
    /// reorder-queue waits, feeding the real path's admission-control
    /// ladder (inert unless the config arms `shed` — then identical).
    fn query_batch_timed(
        &mut self,
        batch: &[(u32, String, usize)],
        waits: &[f64],
    ) -> Vec<anyhow::Result<proto::QueryResult>> {
        self.server.serve_proto_batch_timed(
            batch, waits, &self.tok, &self.cfg,
        )
    }

    /// Non-blocking entry for the `--speculate` event loop: real PJRT
    /// speculative prefills overlapped with the staged search.
    fn submit_session(
        &mut self,
        ticket: u64,
        target_doc: u32,
        query: &str,
        max_new: usize,
    ) -> Option<anyhow::Result<proto::QueryResult>> {
        self.bridge.submit(
            &mut self.server,
            ticket,
            target_doc,
            query,
            max_new,
            &self.tok,
            &self.cfg,
        )
    }

    fn submit_session_timed(
        &mut self,
        ticket: u64,
        target_doc: u32,
        query: &str,
        max_new: usize,
        wait: f64,
    ) -> Option<anyhow::Result<proto::QueryResult>> {
        self.bridge.submit_timed(
            &mut self.server,
            ticket,
            target_doc,
            query,
            max_new,
            wait,
            &self.tok,
            &self.cfg,
        )
    }

    fn poll_sessions(
        &mut self,
        timeout: std::time::Duration,
    ) -> Vec<ragcache::server::SessionDone> {
        self.bridge
            .poll(&mut self.server, timeout, &self.tok, &self.cfg)
            .into_iter()
            .map(|(ticket, result)| ragcache::server::SessionDone {
                ticket,
                result,
            })
            .collect()
    }

    fn sessions_in_flight(&self) -> usize {
        self.server.in_flight_sessions()
    }

    fn stats(&self) -> proto::StatsResult {
        self.server.proto_stats()
    }
}

/// CI matrix mode: the concurrent TCP runtime with real PJRT engines.
fn serve_tcp_matrix(
    dir: &Path,
    workers: usize,
    engines: usize,
    max_batch: usize,
    speculate: bool,
    chunk_cache: bool,
    boundary_tokens: usize,
) -> anyhow::Result<()> {
    let manifest = ArtifactManifest::load(dir)?;
    let mm = manifest.model("tiny-gqa")?;
    let kv_floats = mm.arch.kv_floats_per_token();
    let cfg = RealConfig {
        speculate,
        spec_pool: max_batch,
        chunk_cache,
        boundary_tokens,
        ..RealConfig::default()
    };
    // One sharded tree (one shard per engine) shared by all replicas.
    let cache = RealServer::build_sharded_cache(kv_floats, &cfg, engines);

    let est = cache.clone();
    let estimator: PriorityEstimator = Arc::new(move |req| match req {
        proto::Request::Query { target_doc, .. } => {
            let m = est.lookup(&[*target_doc]);
            (m.cached_tokens, 64usize.saturating_sub(m.cached_tokens).max(1))
        }
        _ => (0, 1),
    });
    // Affinity hint: route by target doc (retrieval's top hit can
    // differ under noise; per-shard locks keep that correct).
    let route = cache.clone();
    let router: ShardFn = Arc::new(move |req| match req {
        proto::Request::Query { target_doc, .. } => {
            route.shard_of_doc(*target_doc)
        }
        _ => 0,
    });
    let opts = ServerOptions {
        workers,
        engines,
        max_batch,
        speculate,
        estimator: Some(estimator),
        router: Some(router),
        ..ServerOptions::default()
    };
    let dir_buf = dir.to_path_buf();
    let engine_cache = cache.clone();
    let handler_cfg = cfg.clone();
    let server = Server::spawn_sharded(0, opts, move |engine| {
        let manifest = ArtifactManifest::load(&dir_buf)?;
        let model = PjrtModel::load(manifest.model("tiny-gqa")?)?;
        let (doc_tokens, em, index) = build_corpus();
        let rs = RealServer::with_cache(
            model,
            index,
            em,
            doc_tokens,
            engine_cache.clone(),
        )?;
        log::info!("engine {engine} ready");
        Ok(TcpHandler {
            server: rs,
            cfg: handler_cfg.clone(),
            tok: ByteTokenizer::new(),
            bridge: SessionProtoBridge::new(),
        })
    })?;
    let addr = server.addr;
    println!(
        "e2e TCP matrix on {addr}: {workers} workers, {engines} engines, \
         {max_batch}-request batches, speculation {}",
        if speculate { "on" } else { "off" }
    );

    // The direct-mode workload, split across parallel clients.
    let workload = skewed_workload();
    let clients = workers.clamp(1, 4);
    let chunk = workload.len().div_ceil(clients);
    let mut joins = Vec::new();
    for part in workload.chunks(chunk) {
        let part = part.to_vec();
        joins.push(std::thread::spawn(
            move || -> anyhow::Result<(usize, usize)> {
                let mut cl = Client::connect(addr)?;
                let mut served = 0usize;
                let mut hits = 0usize;
                for &t in &part {
                    let req = proto::Request::Query {
                        target_doc: t,
                        query: "what is this topic".into(),
                        max_new: 4,
                    };
                    match cl.call(&req)? {
                        proto::Response::Query(q) => {
                            served += 1;
                            if q.docs_hit > 0 {
                                hits += 1;
                            }
                        }
                        other => anyhow::bail!("unexpected {other:?}"),
                    }
                }
                Ok((served, hits))
            },
        ));
    }
    let mut served = 0usize;
    let mut hits = 0usize;
    for j in joins {
        let (s, h) = j.join().expect("client thread")?;
        served += s;
        hits += h;
    }

    // Warm sweep over the hot set, stats, shutdown — all on ONE
    // connection: a connection owns its worker for its lifetime, so
    // with --workers 1 a second admin client would wait out the idle
    // timeout behind this one.
    let mut cl = Client::connect(addr)?;
    let mut warm_hits = 0usize;
    for t in 0..8u32 {
        let req = proto::Request::Query {
            target_doc: t,
            query: "again".into(),
            max_new: 2,
        };
        match cl.call(&req)? {
            proto::Response::Query(q) => {
                if q.docs_hit > 0 {
                    warm_hits += 1;
                }
            }
            other => anyhow::bail!("unexpected {other:?}"),
        }
    }
    let stats = match cl.call(&proto::Request::Stats)? {
        proto::Response::Stats(s) => s,
        other => anyhow::bail!("unexpected stats response {other:?}"),
    };
    let shutdown_ok = cl.call(&proto::Request::Shutdown)?;
    server.join();

    println!(
        "served {served}/{} + {warm_hits}/8 warm hits; stats: {} reqs, \
         {} engines, {} inserts",
        workload.len(),
        stats.requests,
        stats.engines,
        stats.tree_inserts
    );

    // CI gates: regressions exit non-zero, not just print odd numbers.
    let mut failures = Vec::new();
    if shutdown_ok != proto::Response::Ok {
        failures.push(format!("shutdown answered {shutdown_ok:?}"));
    }
    if served != workload.len() {
        failures.push(format!(
            "served {served} of {} requests",
            workload.len()
        ));
    }
    if hits == 0 {
        failures.push("no request ever hit the cache".to_string());
    }
    if warm_hits == 0 {
        failures.push("warm sweep never hit the cache".to_string());
    }
    if stats.engines != engines {
        failures.push(format!(
            "stats merged {} engines, expected {engines}",
            stats.engines
        ));
    }
    if stats.requests != workload.len() + 8 {
        failures.push(format!(
            "stats saw {} requests, expected {}",
            stats.requests,
            workload.len() + 8
        ));
    }
    let c = cache.counters();
    if c.inserts == 0 {
        failures.push("nothing was inserted into the tree".to_string());
    }
    cache.check_invariants();
    if cache.pinned_nodes() != 0 {
        failures.push(format!(
            "{} pins leaked by serving",
            cache.pinned_nodes()
        ));
    }
    if !failures.is_empty() {
        for f in &failures {
            eprintln!("FAIL: {f}");
        }
        std::process::exit(1);
    }
    println!("\nOK");
    Ok(())
}

/// Original composition proof: drive the stack directly, no TCP.
fn serve_direct(dir: &Path) -> anyhow::Result<()> {
    let manifest = ArtifactManifest::load(dir)?;
    let mm = manifest.model("tiny-gqa")?;
    println!(
        "loading {} ({} buckets, {} params) via PJRT...",
        mm.name,
        mm.buckets.len(),
        mm.param_specs.len()
    );
    let model = PjrtModel::load(mm)?;
    println!("platform: {}", model.platform_name());

    // Knowledge base: 128 short documents with real embeddings + index.
    let (doc_tokens, em, index) = build_corpus();
    let mut rng = Rng::new(0xE2E0);

    let cfg = RealConfig::default();
    let mut server = RealServer::new(model, index, em, doc_tokens, &cfg)?;

    let workload = skewed_workload();

    println!("\nserving {} requests (cold + warm)...", workload.len());
    let mut cold = Summary::new();
    let mut warm = Summary::new();
    let t0 = std::time::Instant::now();
    for (i, &target) in workload.iter().enumerate() {
        let query: Vec<i32> =
            (0..24).map(|_| rng.index(256) as i32).collect();
        let resp = server.serve(target, &query, 4, &cfg)?;
        if resp.docs_hit == 0 {
            cold.add(resp.ttft);
        } else {
            warm.add(resp.ttft);
        }
        if i < 4 || i % 16 == 0 {
            println!(
                "  req {:>2}: docs {:?} hit {}/{} cached {:>3} tokens, \
                 ttft {:>7.1} ms",
                i,
                resp.docs,
                resp.docs_hit,
                resp.docs.len(),
                resp.cached_tokens,
                resp.ttft * 1e3
            );
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    let r = server.recorder();
    let mut ttft = r.ttft();
    let n = r.len();
    let hit_rate = r.hit_rate();
    let token_hit = r.token_hit_rate();
    println!("\n== e2e results (real PJRT compute) ==");
    println!("requests           : {}", n);
    println!("throughput         : {:.2} req/s", n as f64 / wall);
    println!(
        "TTFT mean/p50/p99  : {:.1} / {:.1} / {:.1} ms",
        ttft.mean() * 1e3,
        ttft.median() * 1e3,
        ttft.p99() * 1e3
    );
    println!(
        "cold-miss TTFT     : {:.1} ms over {} requests",
        cold.mean() * 1e3,
        cold.len()
    );
    println!(
        "cache-hit TTFT     : {:.1} ms over {} requests",
        warm.mean() * 1e3,
        warm.len()
    );
    println!("doc hit rate       : {:.1}%", hit_rate * 100.0);
    println!("token hit rate     : {:.1}%", token_hit * 100.0);
    let c = server.cache().counters();
    println!(
        "tree               : {} inserts, {} gpu evictions, {} host \
         evictions",
        c.inserts, c.gpu_evictions, c.host_evictions
    );
    if warm.len() > 0 && cold.len() > 0 {
        println!(
            "\ncaching speedup    : {:.2}x (hit vs miss TTFT)",
            cold.mean() / warm.mean()
        );
    }

    // CI gate: regressions must make the example exit non-zero, not just
    // print odd numbers.
    let mut failures = Vec::new();
    if n != workload.len() {
        failures.push(format!(
            "served {n} of {} requests",
            workload.len()
        ));
    }
    if warm.len() == 0 {
        failures.push("no request ever hit the cache".to_string());
    }
    if hit_rate <= 0.0 {
        failures.push(format!("doc hit rate {hit_rate} not positive"));
    }
    if c.inserts == 0 {
        failures.push("nothing was inserted into the tree".to_string());
    }
    server.cache().check_invariants();
    if !failures.is_empty() {
        for f in &failures {
            eprintln!("FAIL: {f}");
        }
        std::process::exit(1);
    }
    println!("\nOK");
    Ok(())
}
