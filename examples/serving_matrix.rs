//! Concurrent serving matrix (PJRT-free): the multi-worker /
//! multi-engine TCP runtime over the sharded knowledge-tree cache, with
//! a synthetic engine standing in for PJRT. Exercises exactly the
//! concurrency surface of `ragcache serve` — connection workers,
//! shard-affinity routing, M engine drivers, cross-engine stats fan-out,
//! graceful shutdown — without AOT artifacts, so CI can sweep a
//! `{workers} × {engines} × {speculate}` matrix everywhere. Exits
//! non-zero on any regression.
//!
//! `--speculate on` serves through the event-driven session lifecycle:
//! a real `FlatIndex` staged search on the retrieval thread pool,
//! Algorithm 2 per stage, pin-only speculative admissions with a
//! synthetic prefill, promotion/fallback on the final stage.
//!
//! `--compare-speculation` runs the acceptance comparison instead: the
//! same cold-cache workload against a speculation-off server and a
//! speculation-on server, with retrieval latency ≥ prefill latency, and
//! requires the summed TTFT with speculation to be strictly lower.
//!
//! `--compare-rebalance` runs the cross-shard rebalancing gate: the
//! same request sequence against a static-split server and a
//! rebalance-on server over a K=4 sharded cache whose GPU budget is too
//! small for the hot shard's working set. Rebalance-on must win
//! aggregate GPU cache-hit bytes strictly on the Zipfian workload and
//! must not lose on the uniform one, with the capacity-conservation
//! invariant checked after serving.
//!
//! `--compare-chunk-cache` runs the chunk-reuse acceptance gate: the
//! same Zipfian document-pair stream, served with `--chunk-cache off`
//! and `on`. On the REORDERED stream (each request flips its pair's
//! doc order at random, so the prefix tree keeps missing) chunk-cache
//! on must strictly reduce both the summed prefill tokens (Σβ) and the
//! transfer+prefill TTFT proxy; on the in-order stream it must not
//! lose either.
//!
//! `--shed on` arms the real-path admission-control ladder inside each
//! engine: every queue pop feeds the wall-clock queue-delay EWMA,
//! requests queued past `--ttft-slo` are shed before any admission
//! work, and while the EWMA holds above the downgrade threshold the
//! staged search runs single-stage. Stats report
//! shed/goodput/attainment with `slo_enabled` set — no zero-fill.
//!
//! `--compare-shed` runs the overload acceptance gate: the same
//! closed-loop client fleet against a shed-off and a shed-on server
//! whose (blocking, timed) search latency stalls the queue well past
//! the TTFT SLO. Shed-on must strictly win requests completed within
//! the SLO, with exact `completed + shed == submitted` accounting on
//! both the client and stats sides.
//!
//! `--disk on` arms the NVMe-backed third tier under every shard:
//! host-pressure evictions demote to disk slots instead of dropping,
//! and later admissions restage the bytes disk→host→GPU, charged as
//! one coalesced read burst per batch. `--cag auto` (requires
//! `--chunk-cache on`) pre-stages the whole corpus as pinned disk
//! chunk entries before serving, the CAG fast path: every request's
//! documents hit the chunk cache without a tree insert.
//!
//! `--compare-disk` runs the disk-tier acceptance gate: the same
//! Zipfian single-document stream against a disk-off and a disk-on
//! server whose host tier is far smaller than the working set. Disk-on
//! must strictly reduce the recompute+transfer TTFT proxy with
//! restage hits > 0 on the thrashing stream, and must not lose on a
//! stream that fits in host.
//!
//! `--compare-cag` runs the corpus-pinning acceptance gate in the
//! discrete-event simulator: a two-tenant open-loop trace served with
//! `--cag off` and `auto` under a pin budget sized to exactly the
//! smaller tenant's corpus. The pinned tenant must complete every
//! request with zero retrieval stages (retrieval_done == arrival,
//! no non-overlapped search) and strictly lower mean TTFT than the
//! same tenant served as cached-RAG.
//!
//! `--bench-serving` emits `bench_out/BENCH_serving.json`: one row per
//! chunk mode with client-measured TTFT p50/p99, throughput and the
//! cache counters, for `ci.sh`'s regression diff against
//! `bench_baselines/`.
//!
//! Run: `cargo run --release --example serving_matrix -- \
//!         --workers 4 --engines 2 [--shards K] [--clients 4]
//!         [--max-batch B] [--speculate on|off] [--rebalance on|off]
//!         [--rebalance-interval N]
//!         [--chunk-cache on|off] [--boundary-tokens R]
//!         [--shed on|off] [--ttft-slo S]
//!         [--disk on|off] [--cag off|auto]
//!         [--compare-speculation] [--compare-rebalance]
//!         [--compare-chunk-cache] [--compare-shed]
//!         [--compare-disk] [--compare-cag] [--bench-serving]`

use ragcache::cli::Args;
use ragcache::config::{PolicyKind, SystemConfig};
use ragcache::controller::{
    split_budget, Admission, BatchAdmission, FinishPath, PipelineDriver,
    RebalanceConfig, RetrievalConfig, RetrievalService, RetrievalTask,
    RetrievalTiming, SessionTable, ShardedCacheService, ShedLadder,
    SimServer, StageReady, TenantMode,
};
use ragcache::embed::EmbeddingModel;
use ragcache::kvcache::PageSpec;
use ragcache::llm::models::ModelSpec;
use ragcache::policy::make_policy;
use ragcache::server::{
    proto, Client, PriorityEstimator, QueryHandler, Server,
    ServerOptions, SessionDone, ShardFn,
};
use ragcache::tree::KnowledgeTree;
use ragcache::vectordb::{FlatIndex, VectorIndex};
use ragcache::workload::{
    tenant_corpora, Corpus, DatasetProfile, Trace, TraceOptions,
};
use std::collections::HashMap;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

const DOC_TOKENS: usize = 32;
const TARGETS: u32 = 16;
const NUM_DOCS: usize = 64;

/// Synthetic-engine driver: no PJRT, no modelled link — the point here
/// is exercising the coalesced-burst *accounting* path, not timing.
struct NullDriver;

impl PipelineDriver for NullDriver {
    fn now(&self) -> f64 {
        0.0
    }
    fn transfer_time(&self, _bytes: u64) -> f64 {
        0.0
    }
}

/// Deterministic corpus embeddings + flat index shared by the session
/// modes (queries are exact document vectors, so retrieval is
/// deterministic across warm and hit phases).
fn build_index(em: &EmbeddingModel) -> Arc<dyn VectorIndex> {
    let vecs: Vec<Vec<f32>> =
        (0..NUM_DOCS as u32).map(|d| em.document(d)).collect();
    Arc::new(FlatIndex::build(em.dim(), &vecs))
}

/// Synthetic latencies of one serving mode.
#[derive(Clone, Copy)]
struct MatrixTiming {
    /// Full-search latency (spread over `stages` in session mode,
    /// charged whole by the blocking mode).
    search: Duration,
    stages: usize,
    /// Synthetic prefill compute per request.
    prefill: Duration,
    top_k: usize,
}

impl MatrixTiming {
    fn fast() -> Self {
        MatrixTiming {
            search: Duration::from_millis(8),
            stages: 4,
            prefill: Duration::from_millis(1),
            top_k: 2,
        }
    }

    /// Retrieval-heavy shape for the acceptance comparison: staged
    /// search latency ≥ prefill latency, targets converging at stage 1.
    fn retrieval_heavy() -> Self {
        MatrixTiming {
            search: Duration::from_millis(100),
            stages: 4,
            prefill: Duration::from_millis(50),
            top_k: 1,
        }
    }
}

/// The session runtime of one speculating engine replica.
struct MatrixSessions {
    service: RetrievalService,
    events: mpsc::Receiver<StageReady>,
    table: SessionTable<Admission>,
    pending: HashMap<u64, MatrixPending>,
    next_session: u64,
    em: EmbeddingModel,
}

struct MatrixPending {
    ticket: u64,
    query: String,
    t0: Instant,
    /// Reorder-queue wait the client already paid before submit (0
    /// with the ladder off) — folded into the reported TTFT.
    wait: f64,
}

/// Per-engine SLO admission-control state (`--shed on`): the real
/// path's ladder over wall-clock queue delay, plus the accounting the
/// stats fan-out reports.
struct MatrixSlo {
    ladder: ShedLadder,
    started: Instant,
    /// TTFT (queue wait + service) of every completed request, ms.
    ttfts_ms: Vec<f64>,
    /// Completions within the TTFT SLO.
    good: u64,
    shed: u64,
    downgraded: u64,
}

impl MatrixSlo {
    fn new(ttft_slo_s: f64) -> Self {
        MatrixSlo {
            ladder: ShedLadder::new(true, ttft_slo_s, 0.5),
            started: Instant::now(),
            ttfts_ms: Vec::new(),
            good: 0,
            shed: 0,
            downgraded: 0,
        }
    }

    /// Wall-clock now in the ladder/table time domain.
    fn now(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    fn complete(&mut self, ttft_ms: f64) {
        if ttft_ms <= self.ladder.ttft_slo() * 1e3 {
            self.good += 1;
        }
        self.ttfts_ms.push(ttft_ms);
    }
}

/// Engine replica: real sharded-cache admission, synthetic compute.
/// `sessions` switches it into the event-driven lifecycle.
struct MatrixHandler {
    cache: ShardedCacheService,
    engine: usize,
    served: u64,
    timing: MatrixTiming,
    /// Blocking mode only: sleep out the search+prefill latencies so
    /// TTFT is comparable against session mode (off for the plain
    /// functional matrix, which wants speed, not timing).
    timed: bool,
    sessions: Option<MatrixSessions>,
    /// `--shed on`: the admission-control ladder; `None` serves the
    /// ladder-free path bit for bit.
    slo: Option<MatrixSlo>,
}

impl MatrixHandler {
    fn admit(&self, docs: &[u32], request_tokens: usize) -> Admission {
        let mut member_bytes = 0u64;
        let docs_tokens: Vec<(u32, usize)> =
            docs.iter().map(|&d| (d, DOC_TOKENS)).collect();
        let batch = BatchAdmission::admit_with(
            &NullDriver,
            std::iter::once(0u64),
            |_| {
                let adm =
                    self.cache.admit(&docs_tokens, request_tokens.max(1));
                member_bytes += adm.transfer_bytes();
                Ok(adm)
            },
        );
        assert_eq!(
            batch.total_bytes(),
            member_bytes,
            "coalesced burst equals the member byte sum"
        );
        batch
            .into_members()
            .pop()
            .map(|(_, a)| a)
            .expect("admission is total")
    }

    /// Commit one admission (its write-back burst sealed through the
    /// shared accounting path) and build the wire result.
    fn commit_result(
        &mut self,
        docs: Vec<u32>,
        adm: Admission,
        query: &str,
        ttft_ms: f64,
    ) -> proto::QueryResult {
        let now = self.served as f64;
        self.cache.touch_hits(&adm, 1e-3, now);
        let out = self.cache.commit(&adm, 1e-3, now, None);
        let mut commits = BatchAdmission::new();
        commits.push_commit(out.transfers);
        commits.seal_commit(&NullDriver);
        self.served += 1;
        proto::QueryResult {
            id: self.served,
            // A chunk hit serves its doc's KV just like a prefix match
            // (modulo the boundary re-prefill), so it counts as hit.
            docs_hit: adm.matched_docs + adm.chunk_hits.len(),
            cached_tokens: adm.alpha,
            computed_tokens: adm.beta,
            ttft_ms,
            total_ms: ttft_ms,
            text: format!("engine{}:{query}", self.engine),
            docs,
        }
    }

    /// Fixed doc pair of the un-indexed (blocking) mode.
    fn pair(target: u32) -> Vec<u32> {
        vec![target, target + 1]
    }

    /// Session submit body, parameterized by the ladder's inputs:
    /// `wait` backdates the table arrival (so deadline expiry measures
    /// what the client saw) and `downgrade` runs the staged search
    /// single-stage — the first stage event is final, so speculation
    /// structurally never starts. (0.0, false) IS the untimed path.
    fn submit_session_inner(
        &mut self,
        ticket: u64,
        target_doc: u32,
        query: &str,
        max_new: usize,
        wait: f64,
        downgrade: bool,
    ) -> Option<anyhow::Result<proto::QueryResult>> {
        let arrival = self
            .slo
            .as_ref()
            .map(|s| s.now() - wait)
            .unwrap_or(0.0);
        let top_k = self.timing.top_k;
        let Some(rt) = self.sessions.as_mut() else {
            return Some(self.query(target_doc, query, max_new));
        };
        let session = rt.next_session;
        rt.next_session += 1;
        rt.table.submit(session, arrival);
        rt.pending.insert(
            session,
            MatrixPending {
                ticket,
                query: query.to_string(),
                t0: Instant::now(),
                wait,
            },
        );
        let accepted = rt.service.submit(RetrievalTask {
            session,
            query: rt.em.document(target_doc),
            top_k,
            stages: if downgrade { Some(1) } else { None },
        });
        if !accepted {
            // Pool gone: the session can never produce stage events —
            // fail it now instead of leaking an admission slot.
            rt.pending.remove(&session);
            rt.table
                .fail(session, "retrieval pool unavailable".to_string());
            rt.table.take_events();
            return Some(Err(anyhow::anyhow!(
                "retrieval pool unavailable"
            )));
        }
        None
    }
}

impl QueryHandler for MatrixHandler {
    fn query(
        &mut self,
        target_doc: u32,
        query: &str,
        max_new: usize,
    ) -> anyhow::Result<proto::QueryResult> {
        self.query_batch(&[(target_doc, query.to_string(), max_new)])
            .pop()
            .expect("one result per query")
    }

    /// Batched admission through the real `BatchAdmission` path: every
    /// member admits (pins) first, the members' promotion transfers
    /// coalesce into one burst, then each member commits — the commit
    /// swap-outs sealing into one write-back burst per batch.
    fn query_batch(
        &mut self,
        batch: &[(u32, String, usize)],
    ) -> Vec<anyhow::Result<proto::QueryResult>> {
        let t0 = Instant::now();
        if self.timed {
            // Blocking shape: the whole search latency, then prefill.
            std::thread::sleep(self.timing.search);
        }
        let cache = &self.cache;
        let mut member_bytes = 0u64;
        let admissions = BatchAdmission::admit_with(
            &NullDriver,
            0..batch.len() as u64,
            |i| {
                let (target_doc, query, _) = &batch[i as usize];
                let docs = Self::pair(*target_doc);
                let docs_tokens: Vec<(u32, usize)> =
                    docs.iter().map(|&d| (d, DOC_TOKENS)).collect();
                let adm = cache.admit(&docs_tokens, query.len().max(1));
                member_bytes += adm.transfer_bytes();
                Ok(adm)
            },
        );
        assert_eq!(
            admissions.total_bytes(),
            member_bytes,
            "coalesced burst equals the member byte sum"
        );
        let mut commit_batch = BatchAdmission::new();
        let results: Vec<anyhow::Result<proto::QueryResult>> = admissions
            .into_members()
            .into_iter()
            .map(|(i, adm)| {
                let (target_doc, query, _) = &batch[i as usize];
                if self.timed {
                    std::thread::sleep(self.timing.prefill);
                }
                let docs = Self::pair(*target_doc);
                let now = self.served as f64;
                self.cache.touch_hits(&adm, 1e-3, now);
                let out = self.cache.commit(&adm, 1e-3, now, None);
                commit_batch.push_commit(out.transfers);
                self.served += 1;
                let ttft_ms = if self.timed {
                    t0.elapsed().as_secs_f64() * 1e3
                } else {
                    1.0
                };
                Ok(proto::QueryResult {
                    id: self.served,
                    docs: docs.clone(),
                    docs_hit: adm.matched_docs + adm.chunk_hits.len(),
                    cached_tokens: adm.alpha,
                    computed_tokens: adm.beta,
                    ttft_ms,
                    total_ms: ttft_ms + 1.0,
                    text: format!("engine{}:{query}", self.engine),
                })
            })
            .collect();
        // Satellite gate: the batch's commit swap-outs charge as ONE
        // write-back burst through the shared accounting path.
        commit_batch.seal_commit(&NullDriver);
        // Cross-shard rebalance tick, one per engine iteration (a no-op
        // unless the shared cache has a rebalancer installed).
        self.cache.maintenance_tick();
        results
    }

    /// [`query_batch`](QueryHandler::query_batch) through the
    /// admission-control ladder: every pop's queue wait feeds the
    /// EWMA, members queued past the TTFT SLO are shed before any
    /// admission work, survivors fold their wait into the reported
    /// TTFT, and while the EWMA holds above the downgrade threshold
    /// the (blocking) staged search runs single-stage. With the
    /// ladder off this IS `query_batch`.
    fn query_batch_timed(
        &mut self,
        batch: &[(u32, String, usize)],
        waits: &[f64],
    ) -> Vec<anyhow::Result<proto::QueryResult>> {
        if self.slo.is_none() {
            return self.query_batch(batch);
        }
        enum Slot {
            Shed(f64),
            Keep(f64),
        }
        let (slo_s, downgrade, slots) = {
            let slo = self.slo.as_mut().expect("checked above");
            let now = slo.now();
            let mut slots = Vec::with_capacity(batch.len());
            for i in 0..batch.len() {
                let wait =
                    waits.get(i).copied().unwrap_or(0.0).max(0.0);
                slo.ladder.observe_wait(wait, now);
                if slo.ladder.should_shed(wait) {
                    slo.shed += 1;
                    slots.push(Slot::Shed(wait));
                } else {
                    slots.push(Slot::Keep(wait));
                }
            }
            (slo.ladder.ttft_slo(), slo.ladder.downgrading(), slots)
        };
        let keep: Vec<(u32, String, usize)> = slots
            .iter()
            .zip(batch)
            .filter(|(s, _)| matches!(s, Slot::Keep(_)))
            .map(|(_, b)| b.clone())
            .collect();
        // Downgrade = single-stage search: the blocking analogue of
        // the session path's `stages: Some(1)`.
        let orig = self.timing;
        if downgrade && self.timed && !keep.is_empty() {
            self.timing.search =
                orig.search / orig.stages.max(1) as u32;
            if let Some(slo) = self.slo.as_mut() {
                slo.downgraded += keep.len() as u64;
            }
        }
        let served = self.query_batch(&keep);
        self.timing = orig;
        let mut served = served.into_iter();
        let mut out = Vec::with_capacity(batch.len());
        for slot in slots {
            match slot {
                Slot::Shed(wait) => out.push(Err(anyhow::anyhow!(
                    "request shed: queued {wait:.3}s past the \
                     {slo_s:.3}s TTFT SLO"
                ))),
                Slot::Keep(wait) => {
                    let r =
                        served.next().expect("one result per survivor");
                    out.push(r.map(|mut q| {
                        // The client paid the queue too.
                        q.ttft_ms += wait * 1e3;
                        q.total_ms += wait * 1e3;
                        if let Some(slo) = self.slo.as_mut() {
                            slo.complete(q.ttft_ms);
                        }
                        q
                    }));
                }
            }
        }
        out
    }

    /// Event-driven entry: dispatch the staged search and return; the
    /// result streams back through `poll_sessions`.
    fn submit_session(
        &mut self,
        ticket: u64,
        target_doc: u32,
        query: &str,
        max_new: usize,
    ) -> Option<anyhow::Result<proto::QueryResult>> {
        self.submit_session_inner(
            ticket, target_doc, query, max_new, 0.0, false,
        )
    }

    /// [`submit_session`](QueryHandler::submit_session) through the
    /// admission-control ladder: the queue wait feeds the EWMA, a
    /// request queued past the SLO is shed before submit, and while
    /// the EWMA holds above the downgrade threshold new sessions run
    /// single-stage. With the ladder off this IS `submit_session`.
    fn submit_session_timed(
        &mut self,
        ticket: u64,
        target_doc: u32,
        query: &str,
        max_new: usize,
        wait: f64,
    ) -> Option<anyhow::Result<proto::QueryResult>> {
        let Some(slo) = self.slo.as_mut() else {
            return self.submit_session(ticket, target_doc, query, max_new);
        };
        let wait = wait.max(0.0);
        let now = slo.now();
        slo.ladder.observe_wait(wait, now);
        if slo.ladder.should_shed(wait) {
            slo.shed += 1;
            let slo_s = slo.ladder.ttft_slo();
            return Some(Err(anyhow::anyhow!(
                "request shed: queued {wait:.3}s past the {slo_s:.3}s \
                 TTFT SLO"
            )));
        }
        let downgrade = slo.ladder.downgrading();
        if downgrade {
            slo.downgraded += 1;
        }
        self.submit_session_inner(
            ticket, target_doc, query, max_new, wait, downgrade,
        )
    }

    /// The event multiplexer body: Algorithm 2 per stage, pin-only
    /// speculative admissions with a synthetic prefill, promote or fall
    /// back on the final stage.
    fn poll_sessions(&mut self, timeout: Duration) -> Vec<SessionDone> {
        // Session-mode rebalance tick (mirrors the real server's
        // per-poll tick).
        self.cache.maintenance_tick();
        let mut out = Vec::new();
        let Some(mut rt) = self.sessions.take() else {
            return out;
        };
        // Admission-control shed pass (mirrors the real server's):
        // sessions whose TTFT deadline expired while still queued
        // behind the staged search are shed — speculation pins
        // released, staged retrieval cancelled, client answered now.
        if let Some(slo) = self.slo.as_mut() {
            let now = slo.now();
            slo.ladder.decay_to(now);
            let slo_s = slo.ladder.ttft_slo();
            for (id, work) in rt.table.shed_expired(now, slo_s) {
                if let Some(w) = work {
                    self.cache.release(&w.payload);
                }
                rt.service.cancel(id);
                let Some(p) = rt.pending.remove(&id) else {
                    continue;
                };
                slo.shed += 1;
                out.push(SessionDone {
                    ticket: p.ticket,
                    result: Err(anyhow::anyhow!(
                        "request shed: TTFT SLO ({slo_s:.3}s) expired \
                         before the final stage"
                    )),
                });
            }
            rt.table.take_events();
        }
        let mut events = Vec::new();
        if let Ok(ev) = rt.events.recv_timeout(timeout) {
            events.push(ev);
        }
        while let Ok(ev) = rt.events.try_recv() {
            events.push(ev);
        }
        for ev in events {
            let id = ev.session;
            if rt.table.session(id).is_none() {
                continue;
            }
            let step =
                rt.table.on_stage(id, ev.stage, &ev.docs, ev.is_final);
            if let Some(work) = step.cancelled {
                self.cache.release(&work.payload);
            }
            if let Some(docs) = step.start {
                let qlen = rt
                    .pending
                    .get(&id)
                    .map(|p| p.query.len())
                    .unwrap_or(1);
                let adm = self.admit(&docs, qlen);
                std::thread::sleep(self.timing.prefill); // spec prefill
                rt.table.spec_started(id, docs, adm);
            }
            if let Some(finish) = step.finish {
                let Some(p) = rt.pending.remove(&id) else {
                    continue;
                };
                let adm = match finish {
                    FinishPath::Promote(work) => work.payload,
                    FinishPath::Fallback => {
                        let adm = self.admit(&ev.docs, p.query.len());
                        std::thread::sleep(self.timing.prefill);
                        adm
                    }
                };
                rt.table.prefilled(id, p.t0.elapsed().as_secs_f64());
                rt.table.decoding(id);
                let ttft_ms = (p.t0.elapsed().as_secs_f64() + p.wait)
                    * 1e3;
                let result = self.commit_result(
                    ev.docs.clone(),
                    adm,
                    &p.query,
                    ttft_ms,
                );
                if let Some(slo) = self.slo.as_mut() {
                    slo.complete(ttft_ms);
                }
                rt.table.complete(id);
                out.push(SessionDone {
                    ticket: p.ticket,
                    result: Ok(result),
                });
            }
            // Lifecycle notifications are internal here.
            rt.table.take_events();
        }
        self.sessions = Some(rt);
        out
    }

    fn sessions_in_flight(&self) -> usize {
        self.sessions
            .as_ref()
            .map(|rt| rt.table.in_flight())
            .unwrap_or(0)
    }

    fn stats(&self) -> proto::StatsResult {
        let c = self.cache.counters();
        let occ = self.cache.shard_occupancies();
        let rb = self.cache.rebalance_stats();
        let spec = self
            .sessions
            .as_ref()
            .map(|rt| rt.table.totals())
            .unwrap_or_default();
        // SLO accounting: live with `--shed on`, explicitly "not
        // measured" (slo_enabled false) otherwise — never a zero-fill
        // that reads as 0% attained.
        let (goodput_rps, ttft_p999_ms, slo_attainment) = self
            .slo
            .as_ref()
            .map(|slo| {
                let mut s = ragcache::util::Summary::new();
                for &t in &slo.ttfts_ms {
                    s.add(t);
                }
                let total = slo.ttfts_ms.len() as u64 + slo.shed;
                (
                    slo.good as f64
                        / slo.started.elapsed().as_secs_f64().max(1e-9),
                    if slo.ttfts_ms.is_empty() { 0.0 } else { s.p999() },
                    if total == 0 {
                        0.0
                    } else {
                        slo.good as f64 / total as f64
                    },
                )
            })
            .unwrap_or((0.0, 0.0, 0.0));
        proto::StatsResult {
            requests: self.served as usize,
            mean_ttft_ms: 1.0,
            hit_rate: 0.0,
            engines: 1,
            tree_inserts: c.inserts,
            tree_gpu_evictions: c.gpu_evictions,
            tree_host_evictions: c.host_evictions,
            spec_started: spec.started,
            spec_wasted: spec.wasted,
            spec_promoted: spec.promoted,
            tree_gpu_hit_bytes: c.gpu_hit_bytes,
            chunk_hits: c.chunk_hits,
            chunk_hit_bytes: c.chunk_hit_bytes,
            boundary_recompute_tokens: c.boundary_recompute_tokens,
            rebalance_recomputes: rb.recomputes,
            rebalance_moved_bytes: rb.gpu_bytes_moved
                + rb.host_bytes_moved,
            shard_gpu_used: occ.iter().map(|o| o.gpu_used).collect(),
            shard_gpu_capacity: occ
                .iter()
                .map(|o| o.gpu_capacity)
                .collect(),
            goodput_rps,
            ttft_p999_ms,
            shed_requests: self.slo.as_ref().map_or(0, |s| s.shed),
            downgraded_requests: self
                .slo
                .as_ref()
                .map_or(0, |s| s.downgraded),
            slo_attainment,
            slo_enabled: self.slo.is_some(),
            disk_spills: c.disk_spills,
            disk_spill_bytes: c.disk_spill_bytes,
            disk_restage_hits: c.disk_restage_hits,
            disk_restage_bytes: c.disk_restage_bytes,
            disk_used: occ.iter().map(|o| o.disk_used).sum(),
            disk_capacity: occ.iter().map(|o| o.disk_capacity).sum(),
            tenants: Vec::new(),
            ext: Vec::new(),
        }
    }
}

fn query(target: u32) -> proto::Request {
    proto::Request::Query {
        target_doc: target,
        query: "q".into(),
        max_new: 1,
    }
}

fn build_cache(
    shards: usize,
    chunk_cache: bool,
    boundary_tokens: usize,
    disk_bytes: u64,
) -> ShardedCacheService {
    let p = PageSpec {
        block_tokens: 8,
        kv_bytes_per_token: 16,
    };
    let disk_split = split_budget(disk_bytes, shards);
    ShardedCacheService::build(shards, |shard| {
        let mut tree = KnowledgeTree::new(
            p.bytes(4096),
            p.bytes(8192),
            p,
            make_policy(PolicyKind::Pgdsf),
            true,
            0,
        );
        if chunk_cache {
            tree.enable_chunk_cache(boundary_tokens);
        }
        if disk_split[shard] > 0 {
            tree.enable_disk_tier(disk_split[shard]);
        }
        tree
    })
}

/// Spawn one matrix server; `speculate`/`timed` pick the serving shape
/// and `ttft_slo` (seconds) arms the per-engine admission-control
/// ladder (`--shed on`).
#[allow(clippy::too_many_arguments)]
fn spawn_matrix(
    svc: &ShardedCacheService,
    workers: usize,
    engines: usize,
    max_batch: usize,
    timing: MatrixTiming,
    speculate: bool,
    timed: bool,
    ttft_slo: Option<f64>,
) -> anyhow::Result<Server> {
    let est = svc.clone();
    let estimator: PriorityEstimator = Arc::new(move |req| match req {
        proto::Request::Query { target_doc, .. } => {
            // Chunk-aware α: a doc reusable at any position counts as
            // cached minus its boundary recompute; with the chunk
            // cache off the reused term is 0 (PR 5 estimator exactly).
            let (m, reused) =
                est.lookup_with_chunks(&[*target_doc, *target_doc + 1]);
            let cached = m.cached_tokens + reused;
            let total = 2 * DOC_TOKENS;
            (cached, total.saturating_sub(cached).max(1))
        }
        _ => (0, 1),
    });
    let route = svc.clone();
    let router: ShardFn = Arc::new(move |req| match req {
        proto::Request::Query { target_doc, .. } => {
            route.shard_of_doc(*target_doc)
        }
        _ => 0,
    });
    let opts = ServerOptions {
        workers,
        engines,
        max_batch,
        speculate,
        estimator: Some(estimator),
        router: Some(router),
        ..ServerOptions::default()
    };
    let handler_svc = svc.clone();
    let server = Server::spawn_sharded(0, opts, move |engine| {
        let sessions = if speculate {
            let em = EmbeddingModel::new(16, 0xE);
            let index = build_index(&em);
            let (tx, rx) = mpsc::channel();
            let service = RetrievalService::spawn(
                index,
                RetrievalConfig {
                    threads: 2,
                    stages: timing.stages,
                    stage_latency: timing.search / timing.stages as u32,
                },
                tx,
            );
            Some(MatrixSessions {
                service,
                events: rx,
                table: SessionTable::new(max_batch),
                pending: HashMap::new(),
                next_session: 0,
                em,
            })
        } else {
            None
        };
        Ok(MatrixHandler {
            cache: handler_svc.clone(),
            engine,
            served: 0,
            timing,
            timed,
            sessions,
            slo: ttft_slo.map(MatrixSlo::new),
        })
    })?;
    Ok(server)
}

/// One `--compare-rebalance` run: serve `targets` serially against a
/// fresh K=4 cache whose GPU budget is deliberately tight, with or
/// without the rebalancer, and report the aggregate GPU cache-hit
/// bytes. Conservation (Σ shard GPU capacity == configured budget) and
/// zero leaked pins are asserted on every run.
fn rebalance_run(
    targets: &[u32],
    rebalance: bool,
) -> anyhow::Result<u64> {
    let p = PageSpec {
        block_tokens: 8,
        kv_bytes_per_token: 16,
    };
    // 1024 GPU tokens over 4 shards: a 256-token static slice holds 8
    // of the 32-token docs, while the Zipfian hot shard's working set
    // is 16 docs — it thrashes unless capacity moves toward it.
    let gpu_total = p.bytes(1024);
    let host_total = p.bytes(16384);
    let gpu_slices = split_budget(gpu_total, 4);
    let host_slices = split_budget(host_total, 4);
    let mut svc = ShardedCacheService::build(4, |i| {
        KnowledgeTree::new(
            gpu_slices[i],
            host_slices[i],
            p,
            ragcache::policy::make_policy(PolicyKind::Pgdsf),
            true,
            0,
        )
    });
    if rebalance {
        svc.enable_rebalancing(RebalanceConfig {
            interval: 10,
            ..RebalanceConfig::default()
        });
    }
    let server = spawn_matrix(
        &svc,
        2,
        1,
        8,
        MatrixTiming::fast(),
        false,
        false,
        None,
    )?;
    let mut cl = Client::connect(server.addr)?;
    for &t in targets {
        match cl.call(&query(t))? {
            proto::Response::Query(_) => {}
            other => anyhow::bail!("unexpected {other:?}"),
        }
    }
    let stats = match cl.call(&proto::Request::Stats)? {
        proto::Response::Stats(s) => s,
        other => anyhow::bail!("unexpected stats response {other:?}"),
    };
    let _ = cl.call(&proto::Request::Shutdown)?;
    server.join();

    let hits = svc.counters().gpu_hit_bytes;
    if stats.tree_gpu_hit_bytes != hits {
        anyhow::bail!(
            "stats hit bytes {} != cache {}",
            stats.tree_gpu_hit_bytes,
            hits
        );
    }
    let caps: u64 = svc
        .shard_occupancies()
        .iter()
        .map(|o| o.gpu_capacity)
        .sum();
    if caps != gpu_total {
        anyhow::bail!(
            "GPU capacity not conserved: {caps} != {gpu_total}"
        );
    }
    if rebalance && stats.rebalance_recomputes == 0 {
        anyhow::bail!("rebalance on but never recomputed");
    }
    if !rebalance && stats.rebalance_moved_bytes != 0 {
        anyhow::bail!("rebalance off but capacity moved");
    }
    svc.check_invariants();
    if svc.pinned_nodes() != 0 {
        anyhow::bail!("{} pins leaked", svc.pinned_nodes());
    }
    Ok(hits)
}

/// Acceptance gate for demand-driven cross-shard rebalancing: on a
/// Zipfian workload whose hot mass routes to one shard, `--rebalance
/// on` must yield strictly more aggregate GPU cache-hit bytes than the
/// static 1/K split; on a uniform workload it must not lose.
fn compare_rebalance() -> anyhow::Result<()> {
    // Zipfian-weighted hot targets, all routing to shard 0 (targets
    // ≡ 0 mod 4; the doc pair [t, t+1] lives under root child t), with
    // a sprinkle of cold traffic on the other shards.
    let mut rng = ragcache::util::Rng::new(0x5EBA1A4C);
    let hot: Vec<u32> = (0..8).map(|i| i * 4).collect();
    let weights: Vec<f64> = (0..hot.len())
        .map(|i| 1.0 / ((i + 1) as f64).powf(1.5))
        .collect();
    let mut zipf = Vec::with_capacity(300);
    for j in 0..300u32 {
        if j % 10 == 9 {
            zipf.push(1 + (j / 10) % 3); // cold: shards 1..3
        } else {
            zipf.push(hot[rng.weighted_index(&weights)]);
        }
    }
    // Uniform: one target per shard, each shard's 2-doc working set
    // within the min-share floor — rebalancing has nothing to win here
    // and, crucially, no slack to lose.
    let uniform: Vec<u32> = (0..300u32).map(|j| j % 4).collect();

    let zipf_off = rebalance_run(&zipf, false)?;
    let zipf_on = rebalance_run(&zipf, true)?;
    let uni_off = rebalance_run(&uniform, false)?;
    let uni_on = rebalance_run(&uniform, true)?;
    println!(
        "  zipfian GPU hit bytes: static {zipf_off}, rebalanced \
         {zipf_on} ({:.2}x)",
        zipf_on as f64 / zipf_off.max(1) as f64
    );
    println!(
        "  uniform GPU hit bytes: static {uni_off}, rebalanced {uni_on}"
    );
    let mut failed = false;
    if zipf_on <= zipf_off {
        eprintln!(
            "FAIL: rebalancing must strictly win GPU hit bytes on the \
             Zipfian workload ({zipf_on} !> {zipf_off})"
        );
        failed = true;
    }
    if uni_on < uni_off {
        eprintln!(
            "FAIL: rebalancing must not lose GPU hit bytes on the \
             uniform workload ({uni_on} < {uni_off})"
        );
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
    println!("OK: rebalancing wins on skew and holds on uniform");
    Ok(())
}

/// One `--compare-chunk-cache` / `--bench-serving` measurement: drive
/// the sharded cache service directly (no TCP, no synthetic sleeps)
/// through the shared admission/commit accounting path over a fixed
/// request stream, and report the summed prefill tokens Σβ, a
/// transfer+prefill TTFT proxy (PCIe-4-ish 16 GB/s link + 50 µs/token
/// prefill), and the chunk-hit count. Conservation and zero leaked
/// pins are asserted after the stream.
fn chunk_stream_run(
    seqs: &[Vec<u32>],
    chunk_cache: bool,
    boundary_tokens: usize,
) -> anyhow::Result<(u64, f64, u64)> {
    let svc = build_cache(1, chunk_cache, boundary_tokens, 0);
    let mut sum_beta = 0u64;
    let mut proxy_s = 0.0f64;
    for (i, docs) in seqs.iter().enumerate() {
        let docs_tokens: Vec<(u32, usize)> =
            docs.iter().map(|&d| (d, DOC_TOKENS)).collect();
        let adm = svc.admit(&docs_tokens, 4);
        let now = i as f64;
        svc.touch_hits(&adm, 1e-3, now);
        let out = svc.commit(&adm, 1e-3, now, None);
        sum_beta += adm.beta as u64;
        let moved = adm.transfer_bytes()
            + out.transfers.h2g_bytes
            + out.transfers.g2h_bytes;
        proxy_s += moved as f64 / 16e9 + adm.beta as f64 * 50e-6;
    }
    svc.check_invariants();
    if svc.pinned_nodes() != 0 {
        anyhow::bail!("{} pins leaked", svc.pinned_nodes());
    }
    Ok((sum_beta, proxy_s, svc.counters().chunk_hits))
}

/// The Zipfian document-pair streams of the chunk-cache gate: 8 pairs
/// of 32-token docs, 200 requests drawn Zipfian(1.5). `reordered`
/// flips each request's pair order on a deterministic RNG bit, which
/// defeats prefix matching while leaving the doc set identical.
fn chunk_streams(reordered: bool) -> Vec<Vec<u32>> {
    let mut rng = ragcache::util::Rng::new(0xC0C_AC4E);
    let weights: Vec<f64> = (0..8)
        .map(|i| 1.0 / ((i + 1) as f64).powf(1.5))
        .collect();
    (0..200)
        .map(|_| {
            let pair = rng.weighted_index(&weights) as u32;
            let (a, b) = (2 * pair, 2 * pair + 1);
            // Draw the flip bit in BOTH modes so the pair sequence is
            // identical between the in-order and reordered streams.
            let flip = rng.index(2) == 1;
            if reordered && flip {
                vec![b, a]
            } else {
                vec![a, b]
            }
        })
        .collect()
}

/// Acceptance gate for chunk-level position-independent reuse: on the
/// reordered Zipfian pair stream, `--chunk-cache on` must strictly
/// reduce both Σβ (summed prefill tokens) and the transfer+prefill
/// TTFT proxy vs off; on the in-order stream it must not lose either.
fn compare_chunk_cache() -> anyhow::Result<()> {
    let mut failed = false;
    for reordered in [true, false] {
        let seqs = chunk_streams(reordered);
        let (beta_off, proxy_off, _) = chunk_stream_run(&seqs, false, 8)?;
        let (beta_on, proxy_on, hits_on) =
            chunk_stream_run(&seqs, true, 8)?;
        let label = if reordered { "reordered" } else { "in-order " };
        println!(
            "  {label}: prefill tokens off {beta_off} on {beta_on} \
             ({:.2}x), ttft proxy off {proxy_off:.4}s on {proxy_on:.4}s, \
             {hits_on} chunk hits",
            beta_off as f64 / beta_on.max(1) as f64
        );
        if reordered {
            if beta_on >= beta_off {
                eprintln!(
                    "FAIL: chunk cache must strictly reduce prefill \
                     tokens on the reordered stream ({beta_on} !< \
                     {beta_off})"
                );
                failed = true;
            }
            if proxy_on >= proxy_off {
                eprintln!(
                    "FAIL: chunk cache must strictly reduce the TTFT \
                     proxy on the reordered stream ({proxy_on:.4} !< \
                     {proxy_off:.4})"
                );
                failed = true;
            }
            if hits_on == 0 {
                eprintln!(
                    "FAIL: reordered stream produced no chunk hits"
                );
                failed = true;
            }
        } else {
            if beta_on > beta_off {
                eprintln!(
                    "FAIL: chunk cache must not lose prefill tokens \
                     in order ({beta_on} > {beta_off})"
                );
                failed = true;
            }
            if proxy_on > proxy_off + 1e-9 {
                eprintln!(
                    "FAIL: chunk cache must not lose the TTFT proxy \
                     in order ({proxy_on:.4} > {proxy_off:.4})"
                );
                failed = true;
            }
        }
    }
    if failed {
        std::process::exit(1);
    }
    println!("OK: chunk reuse wins on reorder and holds in order");
    Ok(())
}

/// Single-shard cache for the `--compare-disk` gate: both upper tiers
/// squeezed far below the thrash stream's working set (GPU holds 8 of
/// the 64 docs, host 16 more), with an NVMe third tier big enough to
/// absorb everything the host drops.
fn disk_cache(disk: bool) -> ShardedCacheService {
    let p = PageSpec {
        block_tokens: 8,
        kv_bytes_per_token: 16,
    };
    ShardedCacheService::build(1, |_| {
        let mut tree = KnowledgeTree::new(
            p.bytes(256),
            p.bytes(512),
            p,
            make_policy(PolicyKind::Pgdsf),
            true,
            0,
        );
        if disk {
            tree.enable_disk_tier(p.bytes(65536));
        }
        tree
    })
}

/// The Zipfian single-document streams of the disk gate. Low skew
/// (1.1) keeps the tail live: with `num_docs` well past the host tier
/// the cascade must keep demoting to disk and restaging back; with a
/// small `num_docs` everything fits in GPU+host and the disk tier must
/// stay idle.
fn disk_streams(num_docs: u32, n: usize) -> Vec<Vec<u32>> {
    let mut rng = ragcache::util::Rng::new(0xD15C_CA4E);
    let weights: Vec<f64> = (0..num_docs)
        .map(|i| 1.0 / ((i + 1) as f64).powf(1.1))
        .collect();
    (0..n)
        .map(|_| vec![rng.weighted_index(&weights) as u32])
        .collect()
}

/// One `--compare-disk` measurement: the admission/commit accounting
/// loop of [`chunk_stream_run`], extended with the disk-tier charges —
/// every restage burst pays its bytes at NVMe read bandwidth
/// (3.5 GB/s) plus one 100 µs access latency per admission that read
/// disk, exactly the simulator's charging contract. The async staging
/// writer is stood in for by draining the queue between requests;
/// spill writes stay uncharged.
fn disk_stream_run(
    seqs: &[Vec<u32>],
    disk: bool,
) -> anyhow::Result<(u64, f64, u64)> {
    let svc = disk_cache(disk);
    let mut sum_beta = 0u64;
    let mut proxy_s = 0.0f64;
    for (i, docs) in seqs.iter().enumerate() {
        let docs_tokens: Vec<(u32, usize)> =
            docs.iter().map(|&d| (d, DOC_TOKENS)).collect();
        let adm = svc.admit(&docs_tokens, 4);
        let now = i as f64;
        svc.touch_hits(&adm, 1e-3, now);
        let out = svc.commit(&adm, 1e-3, now, None);
        sum_beta += adm.beta as u64;
        let moved = adm.transfer_bytes()
            + out.transfers.h2g_bytes
            + out.transfers.g2h_bytes;
        let disk_read =
            adm.disk_read_bytes() + out.transfers.d2h_bytes;
        proxy_s += moved as f64 / 16e9
            + adm.beta as f64 * 50e-6
            + disk_read as f64 / 3.5e9
            + if disk_read > 0 { 100e-6 } else { 0.0 };
        svc.flush_disk_staging();
    }
    svc.check_invariants();
    if svc.pinned_nodes() != 0 {
        anyhow::bail!("{} pins leaked", svc.pinned_nodes());
    }
    Ok((sum_beta, proxy_s, svc.counters().disk_restage_hits))
}

/// Acceptance gate for the NVMe third tier: on a Zipfian stream whose
/// working set thrashes the host tier, `--disk on` must strictly
/// reduce the recompute+transfer TTFT proxy (restaging 512 B at NVMe
/// speed beats re-prefilling 32 tokens) with restage hits actually
/// serving admissions; on a stream that fits in GPU+host it must not
/// lose — the tier is pure downside protection there.
fn compare_disk() -> anyhow::Result<()> {
    let mut failed = false;
    for thrash in [true, false] {
        let (num_docs, n) = if thrash { (64, 400) } else { (12, 400) };
        let seqs = disk_streams(num_docs, n);
        let (beta_off, proxy_off, _) = disk_stream_run(&seqs, false)?;
        let (beta_on, proxy_on, restages) =
            disk_stream_run(&seqs, true)?;
        let label = if thrash { "thrashing" } else { "fits-host" };
        println!(
            "  {label}: prefill tokens off {beta_off} on {beta_on}, \
             ttft proxy off {proxy_off:.4}s on {proxy_on:.4}s, \
             {restages} disk restages"
        );
        if thrash {
            if proxy_on >= proxy_off {
                eprintln!(
                    "FAIL: disk tier must strictly reduce the TTFT \
                     proxy under host thrash ({proxy_on:.4} !< \
                     {proxy_off:.4})"
                );
                failed = true;
            }
            if beta_on >= beta_off {
                eprintln!(
                    "FAIL: disk restages must cut prefill tokens \
                     under host thrash ({beta_on} !< {beta_off})"
                );
                failed = true;
            }
            if restages == 0 {
                eprintln!(
                    "FAIL: thrashing stream never restaged from disk"
                );
                failed = true;
            }
        } else {
            if proxy_on > proxy_off + 1e-9 {
                eprintln!(
                    "FAIL: disk tier must not lose the TTFT proxy \
                     when the set fits ({proxy_on:.4} > \
                     {proxy_off:.4})"
                );
                failed = true;
            }
            if restages != 0 {
                eprintln!(
                    "FAIL: fits-in-host stream read disk {restages} \
                     times"
                );
                failed = true;
            }
        }
    }
    if failed {
        std::process::exit(1);
    }
    println!("OK: disk tier wins under thrash and holds when hot");
    Ok(())
}

/// One `--compare-cag` run in the discrete-event simulator: a
/// two-tenant open-loop MMLU trace over the paper-testbed config with
/// the chunk cache and the disk tier armed. With `cag` the pin budget
/// is sized to exactly the smaller tenant's corpus, so the greedy
/// admitter pins one tenant and leaves the other on the cached-RAG
/// path.
fn cag_run(
    cag: bool,
) -> anyhow::Result<ragcache::controller::SimOutcome> {
    let mut cfg = SystemConfig::default();
    cfg.cache.chunk_cache = true;
    cfg.cache.disk = true;
    cfg.cache.disk_bytes = 64 * (1 << 30);
    cfg.cache.cag = cag;
    let corpus = Corpus::wikipedia_like(400, 2);
    let opts = TraceOptions {
        tenants: 2,
        ..TraceOptions::default()
    };
    let profile = DatasetProfile::lookup("mmlu")?;
    let trace = Trace::generate_open_loop(
        profile, &corpus, 0.5, 40, &opts, 11,
    );
    let mut server = SimServer::build(
        &cfg,
        trace,
        400,
        RetrievalTiming::default(),
        5,
    )?;
    if cag {
        let model = ModelSpec::lookup(&cfg.engine.model)?;
        let page = PageSpec {
            block_tokens: cfg.cache.block_tokens,
            kv_bytes_per_token: model.kv_bytes_per_token,
        };
        let corpora = tenant_corpora(&corpus, &opts);
        let budget =
            corpora.iter().map(|c| c.kv_bytes(page)).min().unwrap();
        server.enable_cag(&corpora, budget);
    }
    Ok(server.run())
}

/// Acceptance gate for CAG-style corpus pinning: exactly one tenant
/// pins under the minimal budget, every one of its requests confirms
/// retrieval at its arrival instant with zero non-overlapped search
/// time, its pinned corpus was actually read back off disk, and its
/// mean TTFT strictly beats the same tenant served as cached-RAG in
/// the `--cag off` run of the identical trace.
fn compare_cag() -> anyhow::Result<()> {
    let off = cag_run(false)?;
    let on = cag_run(true)?;
    let mut failed = false;
    if on.completed != off.completed || on.completed == 0 {
        eprintln!(
            "FAIL: runs must complete the same trace (off {} on {})",
            off.completed, on.completed
        );
        failed = true;
    }
    let cag_tenants: Vec<u32> = on
        .tenant_modes
        .iter()
        .filter(|(_, m)| *m == TenantMode::Cag)
        .map(|(t, _)| *t)
        .collect();
    if cag_tenants.len() != 1 {
        eprintln!(
            "FAIL: minimal budget must pin exactly one tenant, got \
             {:?}",
            on.tenant_modes
        );
        failed = true;
    }
    if on.cag_pinned_bytes == 0 {
        eprintln!("FAIL: pinned tenant holds zero corpus bytes");
        failed = true;
    }
    if on.disk_restage_hits() == 0 {
        eprintln!(
            "FAIL: pinned corpus never restaged off disk — the fast \
             path cannot have served real chunk KV"
        );
        failed = true;
    }
    // Retrieval-free service: the simulator records retrieval_done at
    // the arrival instant and no non-overlapped search for every
    // pinned-tenant request.
    let pinned = cag_tenants.first().copied().unwrap_or(u32::MAX);
    let mut pinned_seen = 0usize;
    for id in 0..on.recorder.len() as u64 {
        let Some(rec) = on.recorder.record(id) else {
            continue;
        };
        if rec.tenant != pinned {
            continue;
        }
        pinned_seen += 1;
        let Some(rd) = rec.retrieval_done else {
            eprintln!("FAIL: pinned request {id} never completed");
            failed = true;
            continue;
        };
        if rd.to_bits() != rec.arrival.to_bits() {
            eprintln!(
                "FAIL: pinned request {id} paid retrieval \
                 ({rd} != arrival {})",
                rec.arrival
            );
            failed = true;
        }
        if rec.non_overlapped_search != 0.0 {
            eprintln!(
                "FAIL: pinned request {id} charged {}s of \
                 non-overlapped search",
                rec.non_overlapped_search
            );
            failed = true;
        }
    }
    if pinned_seen == 0 {
        eprintln!(
            "FAIL: pinned tenant {pinned} served zero requests — \
             the retrieval-free gate never ran"
        );
        failed = true;
    }
    // TTFT gate: the pinned tenant must strictly beat its own
    // cached-RAG service from the `--cag off` run.
    let ttft_of = |out: &ragcache::controller::SimOutcome| {
        out.recorder
            .per_tenant(f64::INFINITY)
            .into_iter()
            .find(|s| s.tenant == pinned)
            .map(|s| s.mean_ttft())
    };
    match (ttft_of(&on), ttft_of(&off)) {
        (Some(t_on), Some(t_off))
            if t_on.is_finite() && t_off.is_finite() =>
        {
            println!(
                "  tenant {pinned}: mean TTFT cached-RAG \
                 {:.1} ms -> CAG {:.1} ms, {} disk restages",
                t_off * 1e3,
                t_on * 1e3,
                on.disk_restage_hits()
            );
            if t_on >= t_off {
                eprintln!(
                    "FAIL: CAG must strictly beat cached-RAG TTFT \
                     for the pinned tenant ({t_on:.6} !< {t_off:.6})"
                );
                failed = true;
            }
        }
        other => {
            eprintln!(
                "FAIL: missing TTFT for pinned tenant: {other:?}"
            );
            failed = true;
        }
    }
    if failed {
        std::process::exit(1);
    }
    println!(
        "OK: one pinned tenant, retrieval-free service, TTFT win"
    );
    Ok(())
}

/// `--bench-serving`: emit `bench_out/BENCH_serving.json` — one row per
/// chunk mode over the reordered Zipfian pair stream (the workload the
/// chunk cache exists for) plus one disk-tier row over the
/// host-thrashing single-doc stream, with wall-clock p50/p99
/// per-request latency and throughput plus the deterministic cache
/// counters. `ci.sh` diffs it against
/// `bench_baselines/BENCH_serving.json`.
fn bench_serving() -> anyhow::Result<()> {
    use ragcache::metrics::registry::{serving_bench_columns, Registry};
    use ragcache::util::json::Json;
    // Column names for the metric-backed columns come from the
    // registry: a stat renamed or dropped there panics here instead of
    // silently forking the bench schema from the wire schema.
    let cols = serving_bench_columns(&Registry::standard());
    let mut r = ragcache::bench::Report::new(
        "BENCH_serving",
        "serving regression bench: reordered Zipfian doc pairs through \
         the shared admission path (chunk cache off vs on), plus the \
         squeezed three-tier cache under the host-thrashing stream \
         (disk on)",
        &cols,
    );
    // SLO cut on the *virtual* transfer+prefill proxy, so the in-SLO
    // count is deterministic: cold pairs (β ≈ 2·DOC_TOKENS → ~3.4 ms)
    // miss it, warm cache hits meet it. Only the /elapsed goodput
    // denominator is wall-clock (loose band via the _rps suffix).
    const SLO_PROXY_S: f64 = 2e-3;
    let pair_seqs = chunk_streams(true);
    let thrash_seqs = disk_streams(64, 400);
    for (chunk, disk) in [(false, false), (true, false), (false, true)]
    {
        let seqs = if disk { &thrash_seqs } else { &pair_seqs };
        let svc = if disk {
            disk_cache(true)
        } else {
            build_cache(1, chunk, 8, 0)
        };
        let mut lat = ragcache::util::Summary::new();
        let t0 = Instant::now();
        let mut sum_beta = 0u64;
        let mut proxy_s = 0.0f64;
        let mut slo_ok = 0usize;
        for (i, docs) in seqs.iter().enumerate() {
            let tq = Instant::now();
            let docs_tokens: Vec<(u32, usize)> =
                docs.iter().map(|&d| (d, DOC_TOKENS)).collect();
            let adm = svc.admit(&docs_tokens, 4);
            let now = i as f64;
            svc.touch_hits(&adm, 1e-3, now);
            let out = svc.commit(&adm, 1e-3, now, None);
            sum_beta += adm.beta as u64;
            let moved = adm.transfer_bytes()
                + out.transfers.h2g_bytes
                + out.transfers.g2h_bytes;
            let disk_read =
                adm.disk_read_bytes() + out.transfers.d2h_bytes;
            let req_proxy = moved as f64 / 16e9
                + adm.beta as f64 * 50e-6
                + disk_read as f64 / 3.5e9
                + if disk_read > 0 { 100e-6 } else { 0.0 };
            proxy_s += req_proxy;
            if req_proxy <= SLO_PROXY_S {
                slo_ok += 1;
            }
            svc.flush_disk_staging();
            lat.add(tq.elapsed().as_secs_f64() * 1e3);
        }
        let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
        svc.check_invariants();
        if svc.pinned_nodes() != 0 {
            anyhow::bail!("{} pins leaked", svc.pinned_nodes());
        }
        let c = svc.counters();
        r.row(vec![
            Json::str(if chunk { "on" } else { "off" }),
            Json::num(seqs.len() as f64),
            Json::num(lat.median()),
            Json::num(lat.p99()),
            Json::num(seqs.len() as f64 / elapsed),
            Json::num(sum_beta as f64),
            Json::num(proxy_s),
            Json::num(c.gpu_hit_bytes as f64),
            Json::num(c.chunk_hits as f64),
            Json::num(c.chunk_hit_bytes as f64),
            Json::num(c.boundary_recompute_tokens as f64),
            Json::num(c.inserts as f64),
            Json::num(c.swap_out_bytes as f64),
            Json::num(slo_ok as f64 / elapsed),
            Json::num(lat.p999()),
            Json::num(0.0), // closed-loop bench never sheds
            Json::str(if disk { "on" } else { "off" }),
            Json::num(c.disk_spills as f64),
            Json::num(c.disk_restage_hits as f64),
            Json::num(c.disk_restage_bytes as f64),
        ]);
    }
    r.note(
        "ttft_p50/p99/p999/throughput/goodput are wall-clock (loose \
         tolerance); token and byte counters (and the in-SLO request \
         count behind goodput) are deterministic; the disk row runs \
         the squeezed three-tier cache over the thrashing stream, so \
         its spill/restage counters are live",
    );
    r.finish();
    Ok(())
}

const SHED_CLIENTS: usize = 10;
const SHED_PER_CLIENT: usize = 6;
const SHED_SLO_S: f64 = 0.25;

/// One `--compare-shed` run: a closed-loop fleet of `SHED_CLIENTS`
/// client threads, each issuing `SHED_PER_CLIENT` requests against a
/// single blocking timed engine whose 60 ms search stalls the queue
/// well past the TTFT SLO. Returns client-observed
/// `(completed_within_slo, completed, shed)` — the within-SLO count is
/// wall-clock around each `call`, so it includes the queue time under
/// BOTH modes (the shed-off server has no ladder folding waits into
/// its reported TTFT).
fn shed_run(shed: bool) -> anyhow::Result<(usize, usize, usize)> {
    let timing = MatrixTiming {
        search: Duration::from_millis(60),
        stages: 4,
        prefill: Duration::ZERO,
        top_k: 1,
    };
    let svc = build_cache(1, false, 8, 0);
    let server = spawn_matrix(
        &svc,
        SHED_CLIENTS,
        1,
        1,
        timing,
        false,
        true,
        shed.then_some(SHED_SLO_S),
    )?;
    let addr = server.addr;
    let mut joins = Vec::new();
    for k in 0..SHED_CLIENTS {
        joins.push(std::thread::spawn(
            move || -> anyhow::Result<(usize, usize, usize)> {
                let mut cl = Client::connect(addr)?;
                let (mut good, mut completed, mut shed_seen) =
                    (0usize, 0usize, 0usize);
                for j in 0..SHED_PER_CLIENT {
                    let t = ((k * SHED_PER_CLIENT + j) % 60) as u32;
                    let t0 = Instant::now();
                    match cl.call(&query(t))? {
                        proto::Response::Query(_) => {
                            completed += 1;
                            if t0.elapsed().as_secs_f64() <= SHED_SLO_S
                            {
                                good += 1;
                            }
                        }
                        proto::Response::Error { message }
                            if message.contains("shed") =>
                        {
                            shed_seen += 1;
                        }
                        other => {
                            anyhow::bail!("unexpected {other:?}")
                        }
                    }
                }
                Ok((good, completed, shed_seen))
            },
        ));
    }
    let (mut good, mut completed, mut shed_seen) = (0, 0, 0);
    for j in joins {
        let (g, c, s) = j.join().expect("client thread")?;
        good += g;
        completed += c;
        shed_seen += s;
    }
    let mut tail = Client::connect(addr)?;
    let stats = match tail.call(&proto::Request::Stats)? {
        proto::Response::Stats(s) => s,
        other => anyhow::bail!("unexpected stats response {other:?}"),
    };
    let _ = tail.call(&proto::Request::Shutdown)?;
    server.join();

    let submitted = SHED_CLIENTS * SHED_PER_CLIENT;
    if completed + shed_seen != submitted {
        anyhow::bail!(
            "accounting: {completed} completed + {shed_seen} shed != \
             {submitted} submitted"
        );
    }
    if stats.slo_enabled != shed {
        anyhow::bail!(
            "slo_enabled {} on a shed-{} run",
            stats.slo_enabled,
            if shed { "on" } else { "off" }
        );
    }
    if stats.shed_requests != shed_seen as u64 {
        anyhow::bail!(
            "stats shed {} != {} shed answers seen by clients",
            stats.shed_requests,
            shed_seen
        );
    }
    if stats.requests != completed {
        anyhow::bail!(
            "stats served {} != {completed} client completions",
            stats.requests
        );
    }
    if !shed && shed_seen != 0 {
        anyhow::bail!("ladder off but {shed_seen} requests shed");
    }
    svc.check_invariants();
    if svc.pinned_nodes() != 0 {
        anyhow::bail!("{} pins leaked", svc.pinned_nodes());
    }
    Ok((good, completed, shed_seen))
}

/// Acceptance gate for real-path admission control: under the same
/// retrieval-stall overload, shed-on must strictly win requests
/// completed within the TTFT SLO — shedding the already-doomed (and
/// downgrading the search while the queue-delay EWMA is high) keeps
/// the queue short enough that fresh requests still make their
/// deadline, where the shed-off server serves everything late.
fn compare_shed() -> anyhow::Result<()> {
    let (good_off, completed_off, _) = shed_run(false)?;
    let (good_on, completed_on, shed_on) = shed_run(true)?;
    println!(
        "  shed off: {good_off}/{completed_off} within the \
         {SHED_SLO_S}s SLO, 0 shed"
    );
    println!(
        "  shed on : {good_on}/{completed_on} within the {SHED_SLO_S}s \
         SLO, {shed_on} shed"
    );
    let mut failed = false;
    if good_on <= good_off {
        eprintln!(
            "FAIL: shed-on must strictly win completions within the \
             SLO ({good_on} !> {good_off})"
        );
        failed = true;
    }
    if shed_on == 0 {
        eprintln!(
            "FAIL: the overload never tripped the ladder (0 shed)"
        );
        failed = true;
    }
    if failed {
        std::process::exit(1);
    }
    println!(
        "OK: admission control lifted within-SLO completions \
         {good_off} -> {good_on} under overload"
    );
    Ok(())
}

/// Acceptance comparison: cold cache, retrieval-heavy timing (staged
/// search latency ≥ prefill latency), identical serial workload.
/// Speculation must strictly lower the summed TTFT: the speculative
/// prefill runs during stages 2..S of the search instead of after it.
fn compare_speculation(workers: usize) -> anyhow::Result<()> {
    let timing = MatrixTiming::retrieval_heavy();
    let requests: Vec<u32> = (0..12).collect(); // ids < NUM_DOCS/stages
    let mut sums = Vec::new();
    for speculate in [false, true] {
        let svc = build_cache(1, false, 8, 0); // fresh cold cache per mode
        let server = spawn_matrix(
            &svc, workers, 1, 8, timing, speculate, !speculate, None,
        )?;
        let mut cl = Client::connect(server.addr)?;
        let mut sum_ms = 0.0;
        for &t in &requests {
            match cl.call(&query(t))? {
                proto::Response::Query(q) => sum_ms += q.ttft_ms,
                other => anyhow::bail!("unexpected {other:?}"),
            }
        }
        let _ = cl.call(&proto::Request::Shutdown)?;
        server.join();
        println!(
            "  speculation {}: summed TTFT {:.1} ms over {} requests",
            if speculate { "on " } else { "off" },
            sum_ms,
            requests.len()
        );
        sums.push(sum_ms);
        svc.check_invariants();
        if svc.pinned_nodes() != 0 {
            anyhow::bail!("{} pins leaked", svc.pinned_nodes());
        }
    }
    if sums[1] >= sums[0] {
        eprintln!(
            "FAIL: speculation-on summed TTFT {:.1} ms !< off {:.1} ms",
            sums[1], sums[0]
        );
        std::process::exit(1);
    }
    println!(
        "OK: speculation cut summed TTFT {:.1} -> {:.1} ms ({:.2}x)",
        sums[0],
        sums[1],
        sums[0] / sums[1]
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(
        &raw,
        &[
            "compare-speculation",
            "compare-rebalance",
            "compare-chunk-cache",
            "compare-shed",
            "compare-disk",
            "compare-cag",
            "bench-serving",
        ],
    )
    .map_err(anyhow::Error::msg)?;
    let workers: usize = args
        .get_parse_or("workers", 4)
        .map_err(anyhow::Error::msg)?;
    let engines: usize = args
        .get_parse_or("engines", 1)
        .map_err(anyhow::Error::msg)?;
    let shards: usize = args
        .get_parse_or("shards", engines.max(1))
        .map_err(anyhow::Error::msg)?;
    let clients: usize = args
        .get_parse_or("clients", 4)
        .map_err(anyhow::Error::msg)?;
    let max_batch: usize = args
        .get_parse_or("max-batch", ServerOptions::default().max_batch)
        .map_err(anyhow::Error::msg)?;
    let speculate = match args.get_or("speculate", "off") {
        "on" => true,
        "off" => false,
        other => anyhow::bail!("--speculate expects on|off, got {other}"),
    };
    let rebalance = match args.get_or("rebalance", "off") {
        "on" => true,
        "off" => false,
        other => anyhow::bail!("--rebalance expects on|off, got {other}"),
    };
    let rebalance_interval: u64 = args
        .get_parse_or("rebalance-interval", 8)
        .map_err(anyhow::Error::msg)?;
    let chunk_cache = match args.get_or("chunk-cache", "off") {
        "on" => true,
        "off" => false,
        other => {
            anyhow::bail!("--chunk-cache expects on|off, got {other}")
        }
    };
    let boundary_tokens: usize = args
        .get_parse_or("boundary-tokens", 8)
        .map_err(anyhow::Error::msg)?;
    if chunk_cache && boundary_tokens == 0 {
        anyhow::bail!(
            "--boundary-tokens must be >= 1 with --chunk-cache on"
        );
    }
    let shed = match args.get_or("shed", "off") {
        "on" => true,
        "off" => false,
        other => anyhow::bail!("--shed expects on|off, got {other}"),
    };
    let ttft_slo_s: f64 = args
        .get_parse_or("ttft-slo", 5.0)
        .map_err(anyhow::Error::msg)?;
    if shed && !(ttft_slo_s > 0.0) {
        anyhow::bail!("--ttft-slo must be > 0 with --shed on");
    }
    let disk = match args.get_or("disk", "off") {
        "on" => true,
        "off" => false,
        other => anyhow::bail!("--disk expects on|off, got {other}"),
    };
    let cag = match args.get_or("cag", "off") {
        "auto" => true,
        "off" => false,
        other => anyhow::bail!("--cag expects off|auto, got {other}"),
    };
    if cag && !chunk_cache {
        anyhow::bail!("--cag auto requires --chunk-cache on");
    }
    if args.flag("compare-speculation") {
        return compare_speculation(workers.max(1));
    }
    if args.flag("compare-shed") {
        return compare_shed();
    }
    if args.flag("compare-rebalance") {
        return compare_rebalance();
    }
    if args.flag("compare-chunk-cache") {
        return compare_chunk_cache();
    }
    if args.flag("compare-disk") {
        return compare_disk();
    }
    if args.flag("compare-cag") {
        return compare_cag();
    }
    if args.flag("bench-serving") {
        return bench_serving();
    }
    if max_batch == 0 {
        anyhow::bail!("--max-batch must be >= 1");
    }
    if shards < engines.max(1) {
        // shard % engines routing would leave the surplus engines idle.
        anyhow::bail!(
            "--shards ({shards}) must be >= --engines ({engines})"
        );
    }

    // Disk budget at the matrix's toy page scale (16 B/token): 1 MiB
    // dwarfs GPU+host, so the third tier absorbs whatever host drops.
    let disk_bytes: u64 = if disk { 1 << 20 } else { 0 };
    let mut svc =
        build_cache(shards, chunk_cache, boundary_tokens, disk_bytes);
    let gpu_budget: u64 = svc
        .shard_occupancies()
        .iter()
        .map(|o| o.gpu_capacity)
        .sum();
    if rebalance {
        svc.enable_rebalancing(RebalanceConfig {
            interval: rebalance_interval.max(1),
            ..RebalanceConfig::default()
        });
    }
    if cag {
        // CAG-style corpus pinning: park every document as a pinned
        // chunk entry before serving (on disk with `--disk on`, host
        // chunk fallback otherwise), then drain the staging queue so
        // the warm sweep already finds them.
        for d in 0..NUM_DOCS as u32 {
            if !svc.prestage_corpus_doc(d, DOC_TOKENS, 0, None) {
                anyhow::bail!("CAG prestage rejected doc {d}");
            }
        }
        svc.flush_disk_staging();
    }
    let server = spawn_matrix(
        &svc,
        workers,
        engines,
        max_batch,
        MatrixTiming::fast(),
        speculate,
        false,
        shed.then_some(ttft_slo_s),
    )?;
    let addr = server.addr;
    println!(
        "serving matrix on {addr}: {workers} workers, {engines} engines, \
         {shards} shards, {clients} clients, {max_batch}-request \
         batches, speculation {}, rebalancing {}, chunk cache {}, \
         admission control {}, disk tier {}, cag {}",
        if speculate { "on" } else { "off" },
        if rebalance { "on" } else { "off" },
        if chunk_cache { "on" } else { "off" },
        if shed {
            format!("on (TTFT SLO {ttft_slo_s}s)")
        } else {
            "off".to_string()
        },
        if disk { "on" } else { "off" },
        if cag { "auto" } else { "off" }
    );

    // Warm phase: one client inserts every target's docs (cold).
    let mut warm = Client::connect(addr)?;
    let mut warm_misses = 0usize;
    for t in 0..TARGETS {
        match warm.call(&query(t))? {
            proto::Response::Query(q) => {
                if q.docs_hit == 0 {
                    warm_misses += 1;
                }
            }
            other => anyhow::bail!("unexpected warm response {other:?}"),
        }
    }
    // A connection owns its worker for its lifetime: with --workers 1
    // an idle warm client would block the hit phase until the idle
    // timeout reclaims it. Yield the worker explicitly.
    drop(warm);

    // Hit phase: parallel clients sweep every target.
    let mut joins = Vec::new();
    for _ in 0..clients.max(1) {
        joins.push(std::thread::spawn(
            move || -> anyhow::Result<(usize, usize)> {
                let mut cl = Client::connect(addr)?;
                let mut served = 0usize;
                let mut full_hits = 0usize;
                for t in 0..TARGETS {
                    match cl.call(&query(t))? {
                        proto::Response::Query(q) => {
                            served += 1;
                            if q.docs_hit == q.docs.len() {
                                full_hits += 1;
                            }
                        }
                        other => {
                            anyhow::bail!("unexpected {other:?}")
                        }
                    }
                }
                Ok((served, full_hits))
            },
        ));
    }
    let mut served = 0usize;
    let mut full_hits = 0usize;
    for j in joins {
        let (s, h) = j.join().expect("client thread")?;
        served += s;
        full_hits += h;
    }

    // Cross-engine stats fan-out, then graceful shutdown — on ONE
    // connection, so no second client waits behind it for a worker.
    let mut tail = Client::connect(addr)?;
    let stats = match tail.call(&proto::Request::Stats)? {
        proto::Response::Stats(s) => s,
        other => anyhow::bail!("unexpected stats response {other:?}"),
    };
    let ok = tail.call(&proto::Request::Shutdown)?;
    server.join();

    let expect_served = clients.max(1) * TARGETS as usize;
    let expect_total = TARGETS as usize + expect_served;
    println!(
        "served {}/{} hit-phase requests, {} full hits, stats: {} reqs \
         across {} engines, {} tree inserts, speculation \
         {}/{}/{} started/wasted/promoted",
        served,
        expect_served,
        full_hits,
        stats.requests,
        stats.engines,
        stats.tree_inserts,
        stats.spec_started,
        stats.spec_wasted,
        stats.spec_promoted,
    );

    // Regression gates: exit non-zero instead of printing odd numbers.
    let mut failures = Vec::new();
    if ok != proto::Response::Ok {
        failures.push(format!("shutdown answered {ok:?}"));
    }
    if !speculate && !chunk_cache && warm_misses != TARGETS as usize {
        // Session mode retrieves real neighbors, whose pairs overlap
        // across targets — cold misses are only exact with the fixed
        // disjoint pairs of the blocking mode. The chunk cache also
        // breaks exactness: warm pairs [t, t+1] overlap on their
        // shared doc, which chunk probing serves position-
        // independently already during the warm sweep.
        failures.push(format!(
            "warm phase: {warm_misses}/{TARGETS} cold misses"
        ));
    }
    if served != expect_served {
        failures.push(format!("served {served} of {expect_served}"));
    }
    if full_hits != served {
        failures.push(format!(
            "only {full_hits}/{served} hit-phase requests fully hit"
        ));
    }
    if stats.engines != engines.max(1) {
        failures.push(format!(
            "stats merged {} engines, expected {}",
            stats.engines,
            engines.max(1)
        ));
    }
    if stats.requests != expect_total {
        failures.push(format!(
            "stats saw {} requests, expected {expect_total}",
            stats.requests
        ));
    }
    let c = svc.counters();
    if speculate {
        // Satellite gate: the speculation counters thread through the
        // stats fan-out, and the staged path actually speculated.
        if stats.spec_started == 0 {
            failures.push("speculation on but never started".to_string());
        }
        if stats.tree_inserts != c.inserts || c.inserts == 0 {
            failures.push(format!(
                "tree inserts: stats {} vs cache {}",
                stats.tree_inserts, c.inserts
            ));
        }
    } else if chunk_cache {
        // Chunk hits serve their doc in place instead of re-inserting
        // it into a fresh prefix chain, so the exact 2×TARGETS insert
        // count of the prefix-only path no longer applies; pin
        // stats/cache consistency and that chunk reuse happened. With
        // CAG the whole corpus is pre-staged, so every doc can serve
        // from its pinned chunk entry without a single insert — the
        // non-zero clause only holds without pinning.
        if stats.tree_inserts != c.inserts || (c.inserts == 0 && !cag) {
            failures.push(format!(
                "tree inserts: stats {} vs cache {}",
                stats.tree_inserts, c.inserts
            ));
        }
        if c.chunk_hits == 0 {
            failures.push("chunk cache on but never hit".to_string());
        }
        if stats.chunk_hits != c.chunk_hits
            || stats.chunk_hit_bytes != c.chunk_hit_bytes
            || stats.boundary_recompute_tokens
                != c.boundary_recompute_tokens
        {
            failures.push(format!(
                "chunk counters: stats {}/{}/{} vs cache {}/{}/{}",
                stats.chunk_hits,
                stats.chunk_hit_bytes,
                stats.boundary_recompute_tokens,
                c.chunk_hits,
                c.chunk_hit_bytes,
                c.boundary_recompute_tokens
            ));
        }
    } else if stats.tree_inserts != c.inserts
        || c.inserts != 2 * TARGETS as u64
    {
        failures.push(format!(
            "tree inserts: stats {} vs cache {} vs expected {}",
            stats.tree_inserts,
            c.inserts,
            2 * TARGETS
        ));
    }
    if !chunk_cache && stats.chunk_hits != 0 {
        failures.push(format!(
            "chunk cache off but {} hits reported",
            stats.chunk_hits
        ));
    }
    // Disk-tier gates: the wire counters mirror the cache exactly, and
    // the capacity gauge tells off (0) from on (> 0). Spills only
    // happen under host pressure, which the fast matrix never builds —
    // so no non-zero demand here; `--compare-disk` covers that.
    if stats.disk_spills != c.disk_spills
        || stats.disk_spill_bytes != c.disk_spill_bytes
        || stats.disk_restage_hits != c.disk_restage_hits
        || stats.disk_restage_bytes != c.disk_restage_bytes
    {
        failures.push(format!(
            "disk counters: stats {}/{}/{}/{} vs cache {}/{}/{}/{}",
            stats.disk_spills,
            stats.disk_spill_bytes,
            stats.disk_restage_hits,
            stats.disk_restage_bytes,
            c.disk_spills,
            c.disk_spill_bytes,
            c.disk_restage_hits,
            c.disk_restage_bytes
        ));
    }
    if disk && stats.disk_capacity == 0 {
        failures.push("disk on but zero capacity reported".to_string());
    }
    if !disk
        && (stats.disk_capacity != 0
            || stats.disk_used != 0
            || stats.disk_spills != 0
            || stats.disk_restage_hits != 0)
    {
        failures.push(
            "disk off but disk stats are non-zero".to_string(),
        );
    }
    if cag && disk && stats.disk_restage_hits == 0 {
        // Pinned corpus entries live on disk; serving them MUST read
        // them back through the restage path at least once.
        failures.push(
            "cag on over disk but no restage ever served".to_string(),
        );
    }
    // Admission-control gates: the wire must say whether the ladder
    // ran; at the generous 5 s default SLO the fast matrix must not
    // shed anything, and with the ladder on every completion is within
    // the SLO (attainment exactly 1).
    if stats.slo_enabled != shed {
        failures.push(format!(
            "slo_enabled {} but --shed {}",
            stats.slo_enabled,
            if shed { "on" } else { "off" }
        ));
    }
    if shed {
        if stats.shed_requests != 0 {
            failures.push(format!(
                "fast matrix shed {} requests at a {ttft_slo_s}s SLO",
                stats.shed_requests
            ));
        }
        if (stats.slo_attainment - 1.0).abs() > 1e-9 {
            failures.push(format!(
                "attainment {} != 1 with nothing shed",
                stats.slo_attainment
            ));
        }
        if stats.goodput_rps <= 0.0 {
            failures.push("ladder on but goodput is zero".to_string());
        }
    } else if stats.shed_requests != 0
        || stats.goodput_rps != 0.0
        || stats.slo_attainment != 0.0
    {
        failures.push(
            "ladder off but SLO counters are non-zero".to_string(),
        );
    }
    // Tentpole gate: whatever the rebalancer did (or didn't — static
    // split), the shard GPU capacities must still sum to the configured
    // budget, bit-exact, and the stats fan-out must expose the same
    // per-shard occupancy the cache reports.
    let occ = svc.shard_occupancies();
    let caps: u64 = occ.iter().map(|o| o.gpu_capacity).sum();
    if caps != gpu_budget {
        failures.push(format!(
            "GPU budget not conserved: {caps} != {gpu_budget}"
        ));
    }
    if stats.shard_gpu_capacity.len() != shards.max(1) {
        failures.push(format!(
            "stats reported {} shard capacity gauges, expected {}",
            stats.shard_gpu_capacity.len(),
            shards.max(1)
        ));
    }
    if !rebalance && stats.rebalance_moved_bytes != 0 {
        failures.push(format!(
            "static split moved {} capacity bytes",
            stats.rebalance_moved_bytes
        ));
    }
    svc.check_invariants();
    if svc.pinned_nodes() != 0 {
        failures.push(format!(
            "{} pins leaked by serving",
            svc.pinned_nodes()
        ));
    }
    if !failures.is_empty() {
        for f in &failures {
            eprintln!("FAIL: {f}");
        }
        std::process::exit(1);
    }
    println!("OK");
    Ok(())
}
