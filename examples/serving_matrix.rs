//! Concurrent serving matrix (PJRT-free): the multi-worker /
//! multi-engine TCP runtime over the sharded knowledge-tree cache, with
//! a synthetic engine standing in for PJRT. Exercises exactly the
//! concurrency surface of `ragcache serve` — connection workers,
//! shard-affinity routing, M engine drivers, cross-engine stats fan-out,
//! graceful shutdown — without AOT artifacts, so CI can sweep a
//! `{workers} × {engines}` matrix everywhere. Exits non-zero on any
//! regression.
//!
//! Run: `cargo run --release --example serving_matrix -- \
//!         --workers 4 --engines 2 [--shards K] [--clients 4]
//!         [--max-batch B]`

use ragcache::cli::Args;
use ragcache::config::PolicyKind;
use ragcache::controller::{
    BatchAdmission, PipelineDriver, ShardedCacheService,
};
use ragcache::kvcache::PageSpec;
use ragcache::policy::make_policy;
use ragcache::server::{
    proto, Client, PriorityEstimator, QueryHandler, Server,
    ServerOptions, ShardFn,
};
use ragcache::tree::KnowledgeTree;
use std::sync::Arc;

const DOC_TOKENS: usize = 32;
const TARGETS: u32 = 16;

/// Synthetic-engine driver: no PJRT, no modelled link — the point here
/// is exercising the coalesced-burst *accounting* path, not timing.
struct NullDriver;

impl PipelineDriver for NullDriver {
    fn now(&self) -> f64 {
        0.0
    }
    fn transfer_time(&self, _bytes: u64) -> f64 {
        0.0
    }
}

/// Engine replica: real sharded-cache admission, synthetic compute.
struct MatrixHandler {
    cache: ShardedCacheService,
    engine: usize,
    served: u64,
}

impl QueryHandler for MatrixHandler {
    fn query(
        &mut self,
        target_doc: u32,
        query: &str,
        max_new: usize,
    ) -> anyhow::Result<proto::QueryResult> {
        self.query_batch(&[(target_doc, query.to_string(), max_new)])
            .pop()
            .expect("one result per query")
    }

    /// Batched admission through the real `BatchAdmission` path: every
    /// member admits (pins) first, the members' promotion transfers
    /// coalesce into one burst, then each member commits. A gate checks
    /// the coalesced totals equal the member sum on every batch.
    fn query_batch(
        &mut self,
        batch: &[(u32, String, usize)],
    ) -> Vec<anyhow::Result<proto::QueryResult>> {
        let cache = &self.cache;
        let mut member_bytes = 0u64;
        let admissions = BatchAdmission::admit_with(
            &NullDriver,
            0..batch.len() as u64,
            |i| {
                let (target_doc, query, _) = &batch[i as usize];
                let docs = [*target_doc, *target_doc + 1];
                let docs_tokens: Vec<(u32, usize)> =
                    docs.iter().map(|&d| (d, DOC_TOKENS)).collect();
                let adm = cache.admit(&docs_tokens, query.len().max(1));
                member_bytes += adm.transfer_bytes();
                Ok(adm)
            },
        );
        assert_eq!(
            admissions.total_bytes(),
            member_bytes,
            "coalesced burst equals the member byte sum"
        );
        admissions
            .into_members()
            .into_iter()
            .map(|(i, adm)| {
                let (target_doc, query, _) = &batch[i as usize];
                let docs = [*target_doc, *target_doc + 1];
                let now = self.served as f64;
                self.cache.touch_hits(&adm, 1e-3, now);
                self.cache.commit(&adm, 1e-3, now, None);
                self.served += 1;
                Ok(proto::QueryResult {
                    id: self.served,
                    docs: docs.to_vec(),
                    docs_hit: adm.matched_docs,
                    cached_tokens: adm.alpha,
                    computed_tokens: adm.beta,
                    ttft_ms: 1.0,
                    total_ms: 2.0,
                    text: format!("engine{}:{query}", self.engine),
                })
            })
            .collect()
    }

    fn stats(&self) -> proto::StatsResult {
        let c = self.cache.counters();
        proto::StatsResult {
            requests: self.served as usize,
            mean_ttft_ms: 1.0,
            hit_rate: 0.0,
            engines: 1,
            tree_inserts: c.inserts,
            tree_gpu_evictions: c.gpu_evictions,
            tree_host_evictions: c.host_evictions,
        }
    }
}

fn query(target: u32) -> proto::Request {
    proto::Request::Query {
        target_doc: target,
        query: "q".into(),
        max_new: 1,
    }
}

fn main() -> anyhow::Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[]).map_err(anyhow::Error::msg)?;
    let workers: usize = args
        .get_parse_or("workers", 4)
        .map_err(anyhow::Error::msg)?;
    let engines: usize = args
        .get_parse_or("engines", 1)
        .map_err(anyhow::Error::msg)?;
    let shards: usize = args
        .get_parse_or("shards", engines.max(1))
        .map_err(anyhow::Error::msg)?;
    let clients: usize = args
        .get_parse_or("clients", 4)
        .map_err(anyhow::Error::msg)?;
    let max_batch: usize = args
        .get_parse_or("max-batch", ServerOptions::default().max_batch)
        .map_err(anyhow::Error::msg)?;
    if max_batch == 0 {
        anyhow::bail!("--max-batch must be >= 1");
    }
    if shards < engines.max(1) {
        // shard % engines routing would leave the surplus engines idle.
        anyhow::bail!(
            "--shards ({shards}) must be >= --engines ({engines})"
        );
    }

    let p = PageSpec {
        block_tokens: 8,
        kv_bytes_per_token: 16,
    };
    let svc = ShardedCacheService::build(shards, |_| {
        KnowledgeTree::new(
            p.bytes(4096),
            p.bytes(8192),
            p,
            make_policy(PolicyKind::Pgdsf),
            true,
            0,
        )
    });
    let est = svc.clone();
    let estimator: PriorityEstimator = Arc::new(move |req| match req {
        proto::Request::Query { target_doc, .. } => {
            let m = est.lookup(&[*target_doc, *target_doc + 1]);
            let total = 2 * DOC_TOKENS;
            (m.cached_tokens, total.saturating_sub(m.cached_tokens).max(1))
        }
        _ => (0, 1),
    });
    let route = svc.clone();
    let router: ShardFn = Arc::new(move |req| match req {
        proto::Request::Query { target_doc, .. } => {
            route.shard_of_doc(*target_doc)
        }
        _ => 0,
    });
    let opts = ServerOptions {
        workers,
        engines,
        max_batch,
        estimator: Some(estimator),
        router: Some(router),
        ..ServerOptions::default()
    };
    let handler_svc = svc.clone();
    let server = Server::spawn_sharded(0, opts, move |engine| {
        Ok(MatrixHandler {
            cache: handler_svc.clone(),
            engine,
            served: 0,
        })
    })?;
    let addr = server.addr;
    println!(
        "serving matrix on {addr}: {workers} workers, {engines} engines, \
         {shards} shards, {clients} clients, {max_batch}-request batches"
    );

    // Warm phase: one client inserts every target's doc pair (cold).
    let mut warm = Client::connect(addr)?;
    let mut warm_misses = 0usize;
    for t in 0..TARGETS {
        match warm.call(&query(t))? {
            proto::Response::Query(q) => {
                if q.docs_hit == 0 {
                    warm_misses += 1;
                }
            }
            other => anyhow::bail!("unexpected warm response {other:?}"),
        }
    }
    // A connection owns its worker for its lifetime: with --workers 1
    // an idle warm client would block the hit phase until the idle
    // timeout reclaims it. Yield the worker explicitly.
    drop(warm);

    // Hit phase: parallel clients sweep every target.
    let mut joins = Vec::new();
    for _ in 0..clients.max(1) {
        joins.push(std::thread::spawn(
            move || -> anyhow::Result<(usize, usize)> {
                let mut cl = Client::connect(addr)?;
                let mut served = 0usize;
                let mut full_hits = 0usize;
                for t in 0..TARGETS {
                    match cl.call(&query(t))? {
                        proto::Response::Query(q) => {
                            served += 1;
                            if q.docs_hit == 2 {
                                full_hits += 1;
                            }
                        }
                        other => {
                            anyhow::bail!("unexpected {other:?}")
                        }
                    }
                }
                Ok((served, full_hits))
            },
        ));
    }
    let mut served = 0usize;
    let mut full_hits = 0usize;
    for j in joins {
        let (s, h) = j.join().expect("client thread")?;
        served += s;
        full_hits += h;
    }

    // Cross-engine stats fan-out, then graceful shutdown — on ONE
    // connection, so no second client waits behind it for a worker.
    let mut tail = Client::connect(addr)?;
    let stats = match tail.call(&proto::Request::Stats)? {
        proto::Response::Stats(s) => s,
        other => anyhow::bail!("unexpected stats response {other:?}"),
    };
    let ok = tail.call(&proto::Request::Shutdown)?;
    server.join();

    let expect_served = clients.max(1) * TARGETS as usize;
    let expect_total = TARGETS as usize + expect_served;
    println!(
        "served {}/{} hit-phase requests, {} full hits, stats: {} reqs \
         across {} engines, {} tree inserts",
        served,
        expect_served,
        full_hits,
        stats.requests,
        stats.engines,
        stats.tree_inserts
    );

    // Regression gates: exit non-zero instead of printing odd numbers.
    let mut failures = Vec::new();
    if ok != proto::Response::Ok {
        failures.push(format!("shutdown answered {ok:?}"));
    }
    if warm_misses != TARGETS as usize {
        failures.push(format!(
            "warm phase: {warm_misses}/{TARGETS} cold misses"
        ));
    }
    if served != expect_served {
        failures.push(format!("served {served} of {expect_served}"));
    }
    if full_hits != served {
        failures.push(format!(
            "only {full_hits}/{served} hit-phase requests fully hit"
        ));
    }
    if stats.engines != engines.max(1) {
        failures.push(format!(
            "stats merged {} engines, expected {}",
            stats.engines,
            engines.max(1)
        ));
    }
    if stats.requests != expect_total {
        failures.push(format!(
            "stats saw {} requests, expected {expect_total}",
            stats.requests
        ));
    }
    let c = svc.counters();
    if stats.tree_inserts != c.inserts || c.inserts != 2 * TARGETS as u64 {
        failures.push(format!(
            "tree inserts: stats {} vs cache {} vs expected {}",
            stats.tree_inserts,
            c.inserts,
            2 * TARGETS
        ));
    }
    svc.check_invariants();
    if svc.pinned_nodes() != 0 {
        failures.push(format!(
            "{} pins leaked by serving",
            svc.pinned_nodes()
        ));
    }
    if !failures.is_empty() {
        for f in &failures {
            eprintln!("FAIL: {f}");
        }
        std::process::exit(1);
    }
    println!("OK");
    Ok(())
}
