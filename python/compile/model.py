"""Layer 2: JAX transformer with prefix-KV reuse (build-time only).

A decoder-only transformer whose prefill consumes a *padded* prefix KV
buffer plus a runtime valid-length scalar — exactly the contract the Rust
coordinator's knowledge tree provides (cached document KV tensors,
order-sensitive, reused across requests). Both attention variants from the
paper's Table 1 are provided: multi-head (LLaMA2-style) and grouped-query
(Mistral-style). The attention hot-spot calls the Layer-1 Pallas kernel.

KV layout is token-major: ``(tokens, layers, 2, n_kv_heads, d_head)``.
Token-major means concatenating prefixes is a flat byte append, which is
what makes vLLM-style block paging on the Rust side trivial.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels.prefix_attention import prefix_attention
from .kernels.ref import prefix_attention_padded_ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (a scaled-down paper Table 1 row)."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_q_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int

    @property
    def kv_floats_per_token(self):
        return self.n_layers * 2 * self.n_kv_heads * self.d_head

    def kv_shape(self, tokens):
        return (tokens, self.n_layers, 2, self.n_kv_heads, self.d_head)


#: Multi-head attention variant (LLaMA2-style: n_q == n_kv heads).
TINY_MHA = ModelConfig(
    name="tiny-mha", vocab=512, d_model=128, n_layers=4,
    n_q_heads=8, n_kv_heads=8, d_head=16, d_ff=512,
)

#: Grouped-query attention variant (Mistral-style: 4 queries per KV head).
TINY_GQA = ModelConfig(
    name="tiny-gqa", vocab=512, d_model=128, n_layers=4,
    n_q_heads=8, n_kv_heads=2, d_head=16, d_ff=512,
)

CONFIGS = {c.name: c for c in (TINY_MHA, TINY_GQA)}


def param_specs(cfg):
    """Ordered (name, shape) list — the flat parameter ABI shared with the
    Rust runtime (artifacts/params manifest)."""
    specs = [("tok_emb", (cfg.vocab, cfg.d_model))]
    for l in range(cfg.n_layers):
        specs += [
            (f"l{l}.attn_norm", (cfg.d_model,)),
            (f"l{l}.wq", (cfg.d_model, cfg.n_q_heads * cfg.d_head)),
            (f"l{l}.wk", (cfg.d_model, cfg.n_kv_heads * cfg.d_head)),
            (f"l{l}.wv", (cfg.d_model, cfg.n_kv_heads * cfg.d_head)),
            (f"l{l}.wo", (cfg.n_q_heads * cfg.d_head, cfg.d_model)),
            (f"l{l}.mlp_norm", (cfg.d_model,)),
            (f"l{l}.w1", (cfg.d_model, cfg.d_ff)),
            (f"l{l}.w2", (cfg.d_ff, cfg.d_model)),
        ]
    specs += [
        ("final_norm", (cfg.d_model,)),
        ("lm_head", (cfg.d_model, cfg.vocab)),
    ]
    return specs


def init_params(cfg, seed=0):
    """Deterministic parameter init; the same flat f32 stream the Rust
    runtime loads from ``artifacts/params_<model>.bin``."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            params.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else 1
            params.append(
                jax.random.normal(sub, shape, jnp.float32)
                * (1.0 / max(fan_in, 1) ** 0.5)
            )
    return params


def _rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def _rope(x, positions):
    """Rotary embeddings; ``x`` is (heads, tokens, d_head), ``positions``
    the absolute token positions (may be traced)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)  # (tokens, half)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1
    )


def prefill_with_prefix(
    cfg, params, prefix_kv, alpha_len, tokens, beta_len, *, use_kernel=True
):
    """Prefill ``tokens`` on top of a cached, padded prefix.

    Args:
      cfg: [`ModelConfig`].
      params: flat parameter list per [`param_specs`].
      prefix_kv: ``(alpha_max, L, 2, Hkv, dh)`` f32 — cached KV, first
        ``alpha_len`` rows valid (RoPE already applied at write time, which
        is what makes document KV order-sensitive, paper §5.1).
      alpha_len: runtime scalar, valid prefix length.
      tokens: ``(beta,)`` int32 token ids, first ``beta_len`` valid.
      beta_len: runtime scalar, valid new-token count.
      use_kernel: route attention through the Pallas kernel (True) or the
        jnp oracle (False) — both lower to the same artifact contract.

    Returns:
      ``(last_logits, new_kv)``: logits of the final *valid* token
      ``(vocab,)`` and ``(beta, L, 2, Hkv, dh)`` new KV rows (rows past
      ``beta_len`` are padding garbage the caller discards).
    """
    alpha_max = prefix_kv.shape[0]
    beta = tokens.shape[0]
    it = iter(params)
    p = {name: next(it) for name, _ in param_specs(cfg)}

    x = p["tok_emb"][tokens]  # (beta, D)
    positions = alpha_len + jnp.arange(beta, dtype=jnp.int32)
    new_kv_layers = []

    for l in range(cfg.n_layers):
        h = _rms_norm(x, p[f"l{l}.attn_norm"])
        q = (h @ p[f"l{l}.wq"]).reshape(beta, cfg.n_q_heads, cfg.d_head)
        k = (h @ p[f"l{l}.wk"]).reshape(beta, cfg.n_kv_heads, cfg.d_head)
        v = (h @ p[f"l{l}.wv"]).reshape(beta, cfg.n_kv_heads, cfg.d_head)

        q = _rope(q.transpose(1, 0, 2), positions)  # (Hq, beta, dh)
        k = _rope(k.transpose(1, 0, 2), positions)  # (Hkv, beta, dh)
        v = v.transpose(1, 0, 2)

        # Cached prefix for this layer: (alpha_max, 2, Hkv, dh).
        k_prefix = prefix_kv[:, l, 0].transpose(1, 0, 2)  # (Hkv, amax, dh)
        v_prefix = prefix_kv[:, l, 1].transpose(1, 0, 2)
        k_full = jnp.concatenate([k_prefix, k], axis=1)
        v_full = jnp.concatenate([v_prefix, v], axis=1)

        if use_kernel:
            attn = prefix_attention(
                q, k_full, v_full, alpha_len, alpha_max=alpha_max
            )
        else:
            attn = prefix_attention_padded_ref(
                q, k_full, v_full, alpha_len, alpha_max=alpha_max
            )

        attn = attn.transpose(1, 0, 2).reshape(beta, -1)
        x = x + attn @ p[f"l{l}.wo"]

        hm = _rms_norm(x, p[f"l{l}.mlp_norm"])
        x = x + jax.nn.silu(hm @ p[f"l{l}.w1"]) @ p[f"l{l}.w2"]

        # Token-major KV rows for the cache: (beta, 2, Hkv, dh).
        new_kv_layers.append(
            jnp.stack(
                [k.transpose(1, 0, 2), v.transpose(1, 0, 2)], axis=1
            )
        )

    x = _rms_norm(x, p["final_norm"])
    logits = x @ p["lm_head"]  # (beta, V)
    last = jax.lax.dynamic_index_in_dim(
        logits, jnp.maximum(beta_len - 1, 0), axis=0, keepdims=False
    )
    new_kv = jnp.stack(new_kv_layers, axis=1)  # (beta, L, 2, Hkv, dh)
    return last, new_kv


def full_prefill(cfg, params, tokens, *, use_kernel=True):
    """Prefill from scratch (no cached prefix): the vLLM-baseline path."""
    beta = tokens.shape[0]
    empty = jnp.zeros(cfg.kv_shape(0), jnp.float32)
    # alpha_max = 0 bucket: concat with 0 prefix slots.
    return prefill_with_prefix(
        cfg, params, empty, 0, tokens, beta, use_kernel=use_kernel
    )


def make_prefill_fn(cfg, *, use_kernel=True):
    """The AOT entry point for one ``(alpha_max, beta)`` bucket: a function
    of ``(params..., prefix_kv, alpha_len, tokens, beta_len)`` returning a
    tuple, as required by the HLO-text interchange."""

    def fn(*args):
        n_params = len(param_specs(cfg))
        params = list(args[:n_params])
        prefix_kv, alpha_len, tokens, beta_len = args[n_params:]
        last, new_kv = prefill_with_prefix(
            cfg, params, prefix_kv, alpha_len, tokens, beta_len,
            use_kernel=use_kernel,
        )
        return (last, new_kv)

    return fn


def greedy_generate(cfg, params, prompt_tokens, steps, *, alpha_max=128,
                    use_kernel=False):
    """Reference greedy decoding used by tests: prefill the prompt then
    decode ``steps`` tokens one at a time through the same prefix path."""
    kv = jnp.zeros(cfg.kv_shape(alpha_max), jnp.float32)
    alpha = 0
    out_tokens = []
    tokens = jnp.asarray(prompt_tokens, jnp.int32)
    last, new_kv = prefill_with_prefix(
        cfg, params, kv, alpha, tokens, tokens.shape[0],
        use_kernel=use_kernel,
    )
    kv = jax.lax.dynamic_update_slice_in_dim(
        kv, new_kv[: tokens.shape[0]], alpha, axis=0
    )
    alpha += int(tokens.shape[0])
    next_tok = int(jnp.argmax(last))
    out_tokens.append(next_tok)
    for _ in range(steps - 1):
        tok = jnp.asarray([next_tok], jnp.int32)
        last, new_kv = prefill_with_prefix(
            cfg, params, kv, alpha, tok, 1, use_kernel=use_kernel
        )
        kv = jax.lax.dynamic_update_slice_in_dim(
            kv, new_kv[:1], alpha, axis=0
        )
        alpha += 1
        next_tok = int(jnp.argmax(last))
        out_tokens.append(next_tok)
    return out_tokens
