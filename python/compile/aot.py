"""AOT compile path: lower the L2 model (with its L1 Pallas kernel) to
HLO *text* artifacts the Rust runtime loads via PJRT.

HLO text — not serialized ``HloModuleProto`` — is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Python runs exactly once at build time (``make artifacts``); the Rust
binary is self-contained afterwards.

Outputs under ``--out-dir`` (default ``../artifacts``):
  - ``prefill_<model>_a<alpha_max>_b<beta>.hlo.txt`` per shape bucket
  - ``params_<model>.bin`` — flat little-endian f32 parameters
  - ``manifest.json`` — the ABI: configs, param specs, buckets
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

#: (alpha_max, beta) shape buckets compiled per model. alpha_len <=
#: alpha_max and beta_len <= beta are runtime scalars, so these few
#: buckets cover every request the end-to-end example issues.
BUCKETS = [(128, 16), (128, 64), (512, 16), (512, 64)]

MODELS = ["tiny-mha", "tiny-gqa"]

PARAM_SEED = 0


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bucket(cfg, alpha_max, beta):
    """Lower one (alpha_max, beta) prefill bucket to HLO text."""
    fn = M.make_prefill_fn(cfg, use_kernel=True)
    specs = [
        jax.ShapeDtypeStruct(shape, jnp.float32)
        for _, shape in M.param_specs(cfg)
    ]
    specs += [
        jax.ShapeDtypeStruct(cfg.kv_shape(alpha_max), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),  # alpha_len
        jax.ShapeDtypeStruct((beta,), jnp.int32),  # tokens
        jax.ShapeDtypeStruct((), jnp.int32),  # beta_len
    ]
    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered)


def write_params(cfg, out_dir):
    params = M.init_params(cfg, seed=PARAM_SEED)
    path = os.path.join(out_dir, f"params_{cfg.name}.bin")
    with open(path, "wb") as f:
        for p in params:
            f.write(np.asarray(p, dtype="<f4").tobytes())
    return os.path.basename(path)


def build(out_dir, models=None, buckets=None):
    os.makedirs(out_dir, exist_ok=True)
    models = models or MODELS
    buckets = buckets or BUCKETS
    manifest = {"version": 1, "models": {}}
    for name in models:
        cfg = M.CONFIGS[name]
        params_file = write_params(cfg, out_dir)
        entry = {
            "config": {
                "vocab": cfg.vocab,
                "d_model": cfg.d_model,
                "n_layers": cfg.n_layers,
                "n_q_heads": cfg.n_q_heads,
                "n_kv_heads": cfg.n_kv_heads,
                "d_head": cfg.d_head,
                "d_ff": cfg.d_ff,
            },
            "param_seed": PARAM_SEED,
            "params_file": params_file,
            "param_specs": [
                [n, list(s)] for n, s in M.param_specs(cfg)
            ],
            "buckets": [],
        }
        for alpha_max, beta in buckets:
            hlo = lower_bucket(cfg, alpha_max, beta)
            fname = f"prefill_{name}_a{alpha_max}_b{beta}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(hlo)
            entry["buckets"].append(
                {"alpha_max": alpha_max, "beta": beta, "hlo": fname}
            )
            print(f"  wrote {fname} ({len(hlo)/1e6:.2f} MB)")
        manifest["models"][name] = entry
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {out_dir}/manifest.json")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", nargs="*", default=None)
    args = ap.parse_args()
    build(args.out_dir, models=args.models)


if __name__ == "__main__":
    main()
