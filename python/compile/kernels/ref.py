"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: straightforward, obviously-right
implementations that the Pallas kernels (and the lowered HLO artifacts)
are checked against in ``python/tests``.
"""

import jax.numpy as jnp


def prefix_attention_ref(q, k, v, *, alpha, sm_scale=None):
    """Reference prefix-caching attention.

    ``beta`` new tokens attend to ``alpha`` cached prefix tokens plus
    causally to the preceding new tokens. This is the operation RAGCache's
    prefix-caching kernel implements (paper §6: the vLLM prefill kernel
    extended for prefix caching, supporting both MHA and GQA).

    Args:
      q: ``(n_q_heads, beta, d_head)`` queries for the new tokens.
      k: ``(n_kv_heads, alpha + beta, d_head)`` keys — cached prefix keys
        concatenated with the new tokens' keys.
      v: ``(n_kv_heads, alpha + beta, d_head)`` values, same layout.
      alpha: number of cached prefix tokens (static).
      sm_scale: softmax scale; defaults to ``1/sqrt(d_head)``.

    Returns:
      ``(n_q_heads, beta, d_head)`` attention output.

    Grouped-query attention: when ``n_q_heads > n_kv_heads``, query head
    ``h`` reads KV head ``h // (n_q_heads // n_kv_heads)``.
    """
    n_q_heads, beta, d_head = q.shape
    n_kv_heads, total, _ = k.shape
    assert total == alpha + beta, (total, alpha, beta)
    assert n_q_heads % n_kv_heads == 0
    group = n_q_heads // n_kv_heads
    if sm_scale is None:
        sm_scale = 1.0 / (d_head ** 0.5)

    # Expand KV heads to query heads.
    k_exp = jnp.repeat(k, group, axis=0)  # (Hq, alpha+beta, d)
    v_exp = jnp.repeat(v, group, axis=0)

    scores = jnp.einsum("hqd,hkd->hqk", q, k_exp) * sm_scale
    # Position of new token i is alpha + i; key j visible iff j <= alpha + i.
    q_pos = alpha + jnp.arange(beta)[:, None]  # (beta, 1)
    k_pos = jnp.arange(alpha + beta)[None, :]  # (1, alpha+beta)
    mask = k_pos <= q_pos
    scores = jnp.where(mask[None, :, :], scores, -jnp.inf)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", probs, v_exp)


def prefix_attention_padded_ref(q, k, v, alpha_len, *, alpha_max,
                                sm_scale=None):
    """Oracle matching the Pallas kernel's padded-bucket signature.

    ``k``/``v`` hold ``alpha_max`` prefix slots (only the first
    ``alpha_len`` valid) followed by ``beta`` new-token slots. Equivalent
    to :func:`prefix_attention_ref` on the compacted buffers.
    """
    n_q_heads, beta, d_head = q.shape
    n_kv_heads, total, _ = k.shape
    assert total == alpha_max + beta
    assert n_q_heads % n_kv_heads == 0
    group = n_q_heads // n_kv_heads
    if sm_scale is None:
        sm_scale = 1.0 / (d_head ** 0.5)

    k_exp = jnp.repeat(k, group, axis=0)
    v_exp = jnp.repeat(v, group, axis=0)
    scores = jnp.einsum("hqd,hkd->hqk", q, k_exp) * sm_scale

    i_idx = jnp.arange(beta)[:, None]
    j_idx = jnp.arange(total)[None, :]
    visible = jnp.where(
        j_idx < alpha_max,
        j_idx < alpha_len,
        (j_idx - alpha_max) <= i_idx,
    )
    scores = jnp.where(visible[None, :, :], scores, -jnp.inf)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", probs, v_exp)
