"""Pallas prefix-caching attention kernel (Layer 1).

This is the compute hot-spot of RAGCache: the prefill attention for a
request whose first ``alpha_len`` tokens (system prompt + retrieved
documents) already have cached key/value tensors, extended from the
vLLM-style prefill kernel the paper modifies (§6). Both multi-head and
grouped-query attention are supported (Table 1 evaluates LLaMA2 = MHA and
Mistral = GQA).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's Triton
kernel tiles with CUDA threadblocks over (head, q-tile) and stages K/V
through shared memory. Here the grid is ``(head, q-tile, k-tile)`` with
the HBM→VMEM schedule expressed via BlockSpec index maps; the k-tile axis
is the revolving innermost dimension with an online-softmax accumulator in
VMEM scratch (flash-attention style), so VMEM residency per step is
``O((block_q + 2*block_k) * d_head)`` independent of the prefix length.
QKᵀ and PV run on the MXU via ``jnp.dot`` with f32 accumulation.

Dynamic lengths: the kernel is compiled for a static ``(alpha_max, beta)``
bucket; the *actual* cached length ``alpha_len <= alpha_max`` arrives as a
runtime scalar (like vLLM's seq-len tensors) and padding slots are masked.
``interpret=True`` always — the CPU PJRT plugin cannot execute Mosaic
custom calls; real-TPU efficiency is estimated analytically (DESIGN.md
§Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _attn_kernel(
    alpha_ref,
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    alpha_max,
    block_q,
    block_k,
    sm_scale,
    n_k_tiles,
):
    """One (head, q-tile, k-tile) grid step.

    Scratch ``acc/m/l`` implement online softmax across the revolving
    k-tile axis; the output block is written on the final k-tile.
    """
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    alpha_len = alpha_ref[0]

    q = q_ref[0].astype(jnp.float32)  # (block_q, d)
    k = k_ref[0].astype(jnp.float32)  # (block_k, d)
    v = v_ref[0].astype(jnp.float32)

    # Scores for this tile pair, f32 accumulation on the MXU.
    s = jax.lax.dot_general(
        q,
        k,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    s = s * sm_scale  # (block_q, block_k)

    # Visibility: slot j in the padded KV buffer is
    #  - a prefix slot   (j < alpha_max):  visible iff j < alpha_len
    #  - a new-token slot (j >= alpha_max): visible iff its new-token index
    #    (j - alpha_max) <= the query's new-token index i  (causal).
    i_idx = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0
    )
    j_idx = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1
    )
    visible = jnp.where(
        j_idx < alpha_max,
        j_idx < alpha_len,
        (j_idx - alpha_max) <= i_idx,
    )
    s = jnp.where(visible, s, NEG_INF)

    # Online softmax update.
    m_prev = m_ref[...]  # (block_q,)
    l_prev = l_ref[...]
    m_cur = jnp.maximum(m_prev, s.max(axis=-1))
    # Row guaranteed non-empty only once a visible key has been seen;
    # exp(-inf - -inf) would be NaN, so guard fully-masked prefixes.
    safe_m = jnp.where(m_cur == NEG_INF, 0.0, m_cur)
    correction = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - safe_m))
    p = jnp.where(visible, jnp.exp(s - safe_m[:, None]), 0.0)
    l_ref[...] = l_prev * correction + p.sum(axis=-1)
    m_ref[...] = m_cur
    pv = jax.lax.dot_general(
        p,
        v,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_ref[...] = acc_ref[...] * correction[:, None] + pv

    @pl.when(ki == n_k_tiles - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def _pad_axis(x, axis, target):
    pad = target - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=(
        "alpha_max",
        "sm_scale",
        "block_q",
        "block_k",
        "interpret",
    ),
)
def prefix_attention(
    q,
    k,
    v,
    alpha_len,
    *,
    alpha_max,
    sm_scale=None,
    block_q=16,
    block_k=64,
    interpret=True,
):
    """Prefix-caching attention over a padded KV buffer.

    Args:
      q: ``(n_q_heads, beta, d_head)`` new-token queries.
      k, v: ``(n_kv_heads, alpha_max + beta, d_head)`` — prefix K/V padded
        to ``alpha_max`` slots, then the new tokens' K/V.
      alpha_len: runtime scalar (int32), number of valid prefix slots.
      alpha_max: static prefix capacity of this compiled bucket.

    Returns:
      ``(n_q_heads, beta, d_head)`` attention output, dtype of ``q``.
    """
    n_q_heads, beta, d_head = q.shape
    n_kv_heads, total, _ = k.shape
    assert total == alpha_max + beta, (total, alpha_max, beta)
    assert n_q_heads % n_kv_heads == 0
    group = n_q_heads // n_kv_heads
    if sm_scale is None:
        sm_scale = 1.0 / (d_head ** 0.5)

    block_q = min(block_q, max(beta, 1))
    beta_pad = -(-beta // block_q) * block_q
    total_pad = -(-total // block_k) * block_k

    # Padded-KV visibility relies on padded slots sitting at indices
    # >= alpha_max + beta with new-token index > any real query index, so
    # pad K/V *after* the new tokens.
    qp = _pad_axis(q, 1, beta_pad)
    kp = _pad_axis(k, 1, total_pad)
    vp = _pad_axis(v, 1, total_pad)

    n_q_tiles = beta_pad // block_q
    n_k_tiles = total_pad // block_k
    grid = (n_q_heads, n_q_tiles, n_k_tiles)

    alpha_arr = jnp.asarray(alpha_len, dtype=jnp.int32).reshape((1,))

    kernel = functools.partial(
        _attn_kernel,
        alpha_max=alpha_max,
        block_q=block_q,
        block_k=block_k,
        sm_scale=sm_scale,
        n_k_tiles=n_k_tiles,
    )

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # The alpha_len scalar is broadcast to every grid step.
            pl.BlockSpec((1,), lambda h, qi, ki: (0,)),
            pl.BlockSpec((1, block_q, d_head), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec(
                (1, block_k, d_head),
                lambda h, qi, ki, g=group: (h // g, ki, 0),
            ),
            pl.BlockSpec(
                (1, block_k, d_head),
                lambda h, qi, ki, g=group: (h // g, ki, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, block_q, d_head), lambda h, qi, ki: (h, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((n_q_heads, beta_pad, d_head), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d_head), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(alpha_arr, qp, kp, vp)

    return out[:, :beta, :]


def vmem_bytes(block_q, block_k, d_head, dtype_bytes=4):
    """Analytic VMEM residency per grid step (DESIGN.md §Perf): one Q tile,
    one K tile, one V tile, the f32 accumulator and the two softmax state
    vectors."""
    q_tile = block_q * d_head * dtype_bytes
    kv_tiles = 2 * block_k * d_head * dtype_bytes
    acc = block_q * d_head * 4
    state = 2 * block_q * 4
    return q_tile + kv_tiles + acc + state


def mxu_utilization_estimate(block_q, block_k, d_head):
    """Fraction of each (128,128,128) MXU pass doing useful work for the
    two dot_generals, assuming f32 packing. Used for the §Perf estimates,
    not measured (interpret mode runs on CPU)."""

    def eff(m, k, n):
        pad = lambda x: -(-x // 128) * 128
        return (m * k * n) / (pad(m) * pad(k) * pad(n))

    qk = eff(block_q, d_head, block_k)
    pv = eff(block_q, block_k, d_head)
    return 0.5 * (qk + pv)
