"""AOT artifact contract tests: manifest, param binaries, HLO text."""

import json
import os
import struct

import numpy as np
import pytest

from compile import aot, model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


class TestManifest:
    def test_models_present(self):
        m = _manifest()
        for name in aot.MODELS:
            assert name in m["models"]

    def test_config_matches_code(self):
        m = _manifest()
        for name, entry in m["models"].items():
            cfg = M.CONFIGS[name]
            c = entry["config"]
            assert c["vocab"] == cfg.vocab
            assert c["n_layers"] == cfg.n_layers
            assert c["n_kv_heads"] == cfg.n_kv_heads
            assert c["d_head"] == cfg.d_head

    def test_param_specs_match_code(self):
        m = _manifest()
        for name, entry in m["models"].items():
            cfg = M.CONFIGS[name]
            want = [[n, list(s)] for n, s in M.param_specs(cfg)]
            assert entry["param_specs"] == want

    def test_buckets_exist_on_disk(self):
        m = _manifest()
        for entry in m["models"].values():
            assert len(entry["buckets"]) >= 2
            for b in entry["buckets"]:
                assert os.path.exists(os.path.join(ART, b["hlo"]))
                assert b["alpha_max"] > 0
                assert b["beta"] > 0


class TestParamBinary:
    def test_size_matches_specs(self):
        m = _manifest()
        for name, entry in m["models"].items():
            cfg = M.CONFIGS[name]
            want_floats = sum(
                int(np.prod(s)) for _, s in M.param_specs(cfg)
            )
            path = os.path.join(ART, entry["params_file"])
            assert os.path.getsize(path) == want_floats * 4

    def test_bytes_match_init(self):
        m = _manifest()
        name = aot.MODELS[0]
        entry = m["models"][name]
        cfg = M.CONFIGS[name]
        params = M.init_params(cfg, seed=entry["param_seed"])
        path = os.path.join(ART, entry["params_file"])
        with open(path, "rb") as f:
            first = struct.unpack("<16f", f.read(64))
        np.testing.assert_allclose(
            first, np.asarray(params[0]).ravel()[:16], rtol=1e-6
        )


class TestHloText:
    def test_hlo_parses_as_module(self):
        m = _manifest()
        entry = next(iter(m["models"].values()))
        path = os.path.join(ART, entry["buckets"][0]["hlo"])
        with open(path) as f:
            text = f.read()
        assert text.startswith("HloModule")
        # Entry computation must exist and return a tuple (return_tuple).
        assert "ENTRY" in text
        assert "tuple(" in text.lower() or "tuple" in text

    def test_hlo_has_expected_parameter_count(self):
        m = _manifest()
        for name, entry in m["models"].items():
            n_inputs = len(entry["param_specs"]) + 4
            path = os.path.join(ART, entry["buckets"][0]["hlo"])
            with open(path) as f:
                text = f.read()
            # Count parameter declarations in the ENTRY computation.
            entry_pos = text.index("ENTRY")
            entry_text = text[entry_pos:]
            count = entry_text.count("parameter(")
            assert count == n_inputs, (name, count, n_inputs)
