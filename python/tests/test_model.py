"""L2 correctness: transformer prefill with prefix-KV reuse.

The decisive property for RAGCache: prefilling on top of cached prefix KV
must be numerically identical to prefilling the whole sequence — and the
cached KV must be order-sensitive (paper §5.1).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Property-based cases need hypothesis; skip the module cleanly when the
# offline environment does not ship it.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def _tokens(rng, n, vocab):
    return jnp.asarray(rng.integers(0, vocab, n), jnp.int32)


@pytest.fixture(scope="module", params=["tiny-mha", "tiny-gqa"])
def model(request):
    cfg = M.CONFIGS[request.param]
    return cfg, M.init_params(cfg, seed=0)


class TestShapes:
    def test_param_specs_cover_params(self, model):
        cfg, params = model
        specs = M.param_specs(cfg)
        assert len(specs) == len(params)
        for (name, shape), p in zip(specs, params):
            assert tuple(shape) == p.shape, name

    def test_prefill_output_shapes(self, model):
        cfg, params = model
        rng = np.random.default_rng(0)
        toks = _tokens(rng, 16, cfg.vocab)
        kv = jnp.zeros(cfg.kv_shape(64), jnp.float32)
        last, new_kv = M.prefill_with_prefix(cfg, params, kv, 0, toks, 16)
        assert last.shape == (cfg.vocab,)
        assert new_kv.shape == cfg.kv_shape(16)

    def test_kv_floats_per_token(self, model):
        cfg, _ = model
        expected = cfg.n_layers * 2 * cfg.n_kv_heads * cfg.d_head
        assert cfg.kv_floats_per_token == expected


class TestKvReuse:
    def test_split_prefill_equals_full(self, model):
        """prefill(full) == prefill(prefix-cached + rest)."""
        cfg, params = model
        rng = np.random.default_rng(1)
        toks = _tokens(rng, 48, cfg.vocab)
        last_full, kv_full = M.full_prefill(cfg, params, toks)
        buf = jnp.zeros(cfg.kv_shape(64), jnp.float32)
        for split in (8, 32, 47):
            last_a, kv_a = M.full_prefill(cfg, params, toks[:split])
            b = buf.at[:split].set(kv_a[:split])
            last_b, kv_b = M.prefill_with_prefix(
                cfg, params, b, split, toks[split:], 48 - split
            )
            np.testing.assert_allclose(
                np.asarray(last_full), np.asarray(last_b), atol=1e-4
            )
            np.testing.assert_allclose(
                np.asarray(kv_full[split:]),
                np.asarray(kv_b[: 48 - split]),
                atol=1e-4,
            )

    def test_document_order_sensitivity(self, model):
        """KV([D1, D2]) != KV([D2, D1]) — the paper's core caching
        constraint (§5.1): the knowledge tree must be order-aware."""
        cfg, params = model
        rng = np.random.default_rng(2)
        d1 = _tokens(rng, 16, cfg.vocab)
        d2 = _tokens(rng, 16, cfg.vocab)
        _, kv_12 = M.full_prefill(cfg, params, jnp.concatenate([d1, d2]))
        _, kv_21 = M.full_prefill(cfg, params, jnp.concatenate([d2, d1]))
        # The KV rows of D2 differ between [D1,D2] and [D2,D1].
        rows_12 = np.asarray(kv_12[16:])  # D2 rows in [D1,D2]
        rows_21 = np.asarray(kv_21[:16])  # D2 rows in [D2,D1]
        assert np.abs(rows_12 - rows_21).max() > 1e-3

    def test_shared_prefix_kv_identical(self, model):
        """Same prefix => byte-identical prefix KV regardless of suffix:
        what makes cross-request sharing sound."""
        cfg, params = model
        rng = np.random.default_rng(3)
        prefix = _tokens(rng, 24, cfg.vocab)
        s1 = _tokens(rng, 8, cfg.vocab)
        s2 = _tokens(rng, 8, cfg.vocab)
        _, kv1 = M.full_prefill(cfg, params, jnp.concatenate([prefix, s1]))
        _, kv2 = M.full_prefill(cfg, params, jnp.concatenate([prefix, s2]))
        np.testing.assert_array_equal(
            np.asarray(kv1[:24]), np.asarray(kv2[:24])
        )

    def test_beta_padding_discarded(self, model):
        """Valid-token results must not depend on padding tokens."""
        cfg, params = model
        rng = np.random.default_rng(4)
        toks = _tokens(rng, 16, cfg.vocab)
        buf = jnp.zeros(cfg.kv_shape(32), jnp.float32)
        padded = jnp.concatenate([toks[:12], _tokens(rng, 4, cfg.vocab)])
        last_a, kv_a = M.prefill_with_prefix(cfg, params, buf, 0, padded, 12)
        padded2 = jnp.concatenate([toks[:12], _tokens(rng, 4, cfg.vocab)])
        last_b, kv_b = M.prefill_with_prefix(
            cfg, params, buf, 0, padded2, 12
        )
        np.testing.assert_allclose(
            np.asarray(last_a), np.asarray(last_b), atol=1e-6
        )
        np.testing.assert_allclose(
            np.asarray(kv_a[:12]), np.asarray(kv_b[:12]), atol=1e-6
        )


class TestGeneration:
    def test_greedy_deterministic(self, model):
        cfg, params = model
        a = M.greedy_generate(cfg, params, [1, 2, 3, 4], 6)
        b = M.greedy_generate(cfg, params, [1, 2, 3, 4], 6)
        assert a == b
        assert len(a) == 6
        assert all(0 <= t < cfg.vocab for t in a)

    def test_greedy_depends_on_prompt(self, model):
        cfg, params = model
        a = M.greedy_generate(cfg, params, [1, 2, 3, 4], 4)
        b = M.greedy_generate(cfg, params, [5, 6, 7, 8], 4)
        assert a != b


@settings(max_examples=10, deadline=None)
@given(
    split=st.integers(min_value=1, max_value=31),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_kv_reuse_invariance_hypothesis(split, seed):
    cfg = M.TINY_GQA
    params = M.init_params(cfg, seed=0)
    rng = np.random.default_rng(seed)
    toks = _tokens(rng, 32, cfg.vocab)
    last_full, _ = M.full_prefill(cfg, params, toks)
    last_a, kv_a = M.full_prefill(cfg, params, toks[:split])
    buf = jnp.zeros(cfg.kv_shape(64), jnp.float32).at[:split].set(
        kv_a[:split]
    )
    last_b, _ = M.prefill_with_prefix(
        cfg, params, buf, split, toks[split:], 32 - split
    )
    np.testing.assert_allclose(
        np.asarray(last_full), np.asarray(last_b), atol=2e-4, rtol=2e-4
    )
