"""L1 correctness: Pallas prefix-attention kernel vs the pure-jnp oracle.

This is the core numeric signal for the whole stack: the AOT artifacts the
Rust runtime executes contain exactly this kernel.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Property-based cases need hypothesis; skip the module cleanly when the
# offline environment does not ship it.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels.prefix_attention import (
    mxu_utilization_estimate,
    prefix_attention,
    vmem_bytes,
)
from compile.kernels.ref import (
    prefix_attention_padded_ref,
    prefix_attention_ref,
)

jax.config.update("jax_platform_name", "cpu")


def _rand(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.normal(size=shape), dtype)


def _run_case(Hq, Hkv, beta, alpha_len, alpha_max, d, dtype, seed,
              block_q=16, block_k=64):
    rng = np.random.default_rng(seed)
    q = _rand(rng, (Hq, beta, d), dtype)
    k = _rand(rng, (Hkv, alpha_max + beta, d), dtype)
    v = _rand(rng, (Hkv, alpha_max + beta, d), dtype)
    out = prefix_attention(
        q, k, v, alpha_len, alpha_max=alpha_max,
        block_q=block_q, block_k=block_k,
    )
    ref = prefix_attention_padded_ref(
        q.astype(jnp.float32),
        k.astype(jnp.float32),
        v.astype(jnp.float32),
        alpha_len,
        alpha_max=alpha_max,
    )
    return np.asarray(out, np.float32), np.asarray(ref, np.float32)


class TestKernelBasic:
    def test_no_prefix(self):
        out, ref = _run_case(8, 8, 16, 0, 64, 16, jnp.float32, 0)
        np.testing.assert_allclose(out, ref, atol=1e-5)

    def test_full_prefix(self):
        out, ref = _run_case(8, 8, 16, 64, 64, 16, jnp.float32, 1)
        np.testing.assert_allclose(out, ref, atol=1e-5)

    def test_partial_prefix(self):
        out, ref = _run_case(8, 8, 32, 37, 64, 16, jnp.float32, 2)
        np.testing.assert_allclose(out, ref, atol=1e-5)

    def test_gqa_grouping(self):
        out, ref = _run_case(8, 2, 16, 40, 64, 16, jnp.float32, 3)
        np.testing.assert_allclose(out, ref, atol=1e-5)

    def test_single_query_decode_shape(self):
        out, ref = _run_case(8, 2, 1, 100, 128, 16, jnp.float32, 4)
        np.testing.assert_allclose(out, ref, atol=1e-5)

    def test_bf16_inputs(self):
        out, ref = _run_case(4, 4, 16, 32, 64, 32, jnp.bfloat16, 5)
        np.testing.assert_allclose(out, ref, atol=3e-2, rtol=3e-2)

    def test_alpha_zero_bucket_zero(self):
        # alpha_max = 0: pure causal self-attention.
        rng = np.random.default_rng(6)
        q = _rand(rng, (4, 24, 16))
        k = _rand(rng, (4, 24, 16))
        v = _rand(rng, (4, 24, 16))
        out = prefix_attention(q, k, v, 0, alpha_max=0)
        ref = prefix_attention_ref(q, k, v, alpha=0)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), atol=1e-5
        )

    def test_causality_no_future_leak(self):
        """Changing a later token's K/V must not change earlier outputs."""
        rng = np.random.default_rng(7)
        q = _rand(rng, (2, 8, 16))
        k = _rand(rng, (2, 40, 16))
        v = _rand(rng, (2, 40, 16))
        out1 = np.asarray(prefix_attention(q, k, v, 32, alpha_max=32))
        # Perturb the last new token's K/V (slot alpha_max + 7).
        k2 = k.at[:, 39].set(99.0)
        v2 = v.at[:, 39].set(-99.0)
        out2 = np.asarray(prefix_attention(q, k2, v2, 32, alpha_max=32))
        np.testing.assert_allclose(out1[:, :7], out2[:, :7], atol=1e-6)
        assert np.abs(out1[:, 7] - out2[:, 7]).max() > 1e-3

    def test_padding_slots_ignored(self):
        """Garbage in prefix slots >= alpha_len must not affect output."""
        rng = np.random.default_rng(8)
        q = _rand(rng, (2, 8, 16))
        k = _rand(rng, (2, 72, 16))
        v = _rand(rng, (2, 72, 16))
        out1 = np.asarray(prefix_attention(q, k, v, 20, alpha_max=64))
        k2 = k.at[:, 20:64].set(1e6)
        v2 = v.at[:, 20:64].set(-1e6)
        out2 = np.asarray(prefix_attention(q, k2, v2, 20, alpha_max=64))
        np.testing.assert_allclose(out1, out2, atol=1e-6)


@settings(max_examples=40, deadline=None)
@given(
    Hq_groups=st.sampled_from([(4, 4), (8, 2), (8, 8), (8, 4), (2, 1)]),
    beta=st.integers(min_value=1, max_value=48),
    alpha_frac=st.floats(min_value=0.0, max_value=1.0),
    alpha_max=st.sampled_from([0, 32, 64, 128]),
    d=st.sampled_from([8, 16, 32]),
    seed=st.integers(min_value=0, max_value=2**31),
    block_q=st.sampled_from([8, 16, 32]),
    block_k=st.sampled_from([16, 64, 128]),
)
def test_kernel_matches_oracle_hypothesis(
    Hq_groups, beta, alpha_frac, alpha_max, d, seed, block_q, block_k
):
    Hq, Hkv = Hq_groups
    alpha_len = int(round(alpha_frac * alpha_max))
    out, ref = _run_case(
        Hq, Hkv, beta, alpha_len, alpha_max, d, jnp.float32, seed,
        block_q=block_q, block_k=block_k,
    )
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


class TestPerfEstimates:
    def test_vmem_fits_16mib(self):
        # Production-shaped tiles must fit comfortably in ~16 MiB VMEM.
        assert vmem_bytes(128, 128, 128) < 16 * 1024 * 1024

    def test_vmem_independent_of_alpha(self):
        assert vmem_bytes(64, 128, 64) == vmem_bytes(64, 128, 64)

    def test_mxu_utilization_full_tiles(self):
        assert mxu_utilization_estimate(128, 128, 128) == pytest.approx(1.0)

    def test_mxu_utilization_small_tiles_penalised(self):
        assert mxu_utilization_estimate(16, 64, 16) < 0.1
